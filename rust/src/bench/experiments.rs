//! Experiment drivers: one per paper table/figure (DESIGN.md experiment
//! index). Each driver regenerates the corresponding rows/series, writes
//! them under `results/` and prints a paper-style summary.
//!
//! | driver   | paper artifact                 |
//! |----------|--------------------------------|
//! | `fig1`   | Fig. 1  (homogeneous consensus)|
//! | `fig2`   | Fig. 2  (node-level consensus) |
//! | `fig4`   | Fig. 4  (intra-server consensus)|
//! | `fig6`   | Fig. 6  (inter-server consensus)|
//! | `table1` | Table I (scalability)          |
//! | `fig7`–`fig10`, `table2` | DSGD curves + time-to-accuracy |
//!
//! Optimized topologies are cached as JSON under `results/topos/` — delete
//! the cache to force re-optimization.

use crate::bandwidth::scenarios::BandwidthScenario;
use crate::bandwidth::timing::TimeModel;
use crate::config;
use crate::consensus::{run_consensus, ConsensusConfig};
use crate::graph::Topology;
use crate::optimizer::{BaTopoOptimizer, OptimizeSpec};
use crate::runtime::mixer::MixVariant;
use crate::runtime::PjRtEngine;
use crate::topo::baselines::{self, Baseline};
use crate::training::{DsgdConfig, DsgdTrainer};
use crate::util::csv::CsvWriter;
use std::path::PathBuf;

/// Options shared by every driver.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Reduced budgets for CI-speed runs.
    pub quick: bool,
    /// Output directory (default `results/`).
    pub out_dir: PathBuf,
    /// Base seed.
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            quick: false,
            out_dir: PathBuf::from("results"),
            seed: 42,
        }
    }
}

/// Tuned optimizer spec: budgets scale down with n so the large Table-I rows
/// stay tractable.
pub fn ba_spec(scenario: BandwidthScenario, r: usize, quick: bool) -> OptimizeSpec {
    let n = scenario.num_nodes();
    let mut s = OptimizeSpec::with_scenario(scenario, r);
    if quick {
        s.max_iters = 60;
        s.anneal_steps = 300;
        s.polish_swaps = 8;
        s.refine_iters = 120;
        s.restarts = 1;
    } else {
        s.max_iters = (24_000 / n.max(1)).clamp(60, 300);
        s.anneal_steps = if n > 64 { 1000 } else { 2000 };
        s.polish_swaps = (2_000 / n.max(1)).clamp(8, 60);
        // Spectral evaluations are O(n³); keep the refinement budget bounded
        // at scale (the weight optimum is flat — see EXPERIMENTS.md §Perf).
        s.refine_iters = if n > 48 { 80 } else { 300 };
        // Restarts recover support diversity where single swaps cannot move
        // (tight capacity packings); cheap at small n, trimmed at scale.
        s.restarts = if n <= 32 { 4 } else { 2 };
    }
    s
}

/// Optimize (or load cached) BA-Topo for a scenario + budget.
pub fn ba_topo_cached(
    scenario: &BandwidthScenario,
    r: usize,
    opts: &ExpOptions,
    key: &str,
) -> Topology {
    let path = opts.out_dir.join("topos").join(format!("{key}.json"));
    if let Ok(t) = config::load_topology(&path) {
        return t;
    }
    let mut spec = ba_spec(scenario.clone(), r, opts.quick);
    spec.seed = opts.seed;
    let topo = BaTopoOptimizer::new(spec)
        .run()
        .unwrap_or_else(|e| panic!("BA-Topo optimization failed for {key}: {e}"));
    config::save_topology(&topo, &path).expect("cache topology");
    topo
}

// ---------------------------------------------------------------------------
// Consensus figures (Figs. 1, 2, 4, 6)
// ---------------------------------------------------------------------------

fn consensus_figure(
    fig: &str,
    scenario: &BandwidthScenario,
    entries: Vec<Topology>,
    opts: &ExpOptions,
) {
    let tm = TimeModel::default();
    let cfg = ConsensusConfig {
        eps: 1e-4,
        max_rounds: if opts.quick { 800 } else { 4000 },
        seed: opts.seed,
        ..Default::default()
    };
    let mut curve = CsvWriter::create(
        opts.out_dir.join(format!("{fig}.csv")),
        &["topology", "edges", "round", "sim_time_s", "error"],
    )
    .expect("csv");
    let mut summary = CsvWriter::create(
        opts.out_dir.join(format!("{fig}_summary.csv")),
        &[
            "topology",
            "edges",
            "r_asym",
            "b_min_gbps",
            "iter_time_ms",
            "time_to_1e-4_ms",
        ],
    )
    .expect("csv");

    println!("── {fig}: consensus under {} bandwidth ──", scenario.name());
    println!(
        "{:<26} {:>6} {:>8} {:>8} {:>12} {:>16}",
        "topology", "edges", "r_asym", "b_min", "t_iter(ms)", "t(err<1e-4) ms"
    );
    for topo in entries {
        let run = run_consensus(None, &topo, scenario, &tm, &cfg).expect("consensus");
        for p in &run.trajectory {
            // Thin the trace: log every point early, then every 8th.
            if p.round > 64 && p.round % 8 != 0 {
                continue;
            }
            curve
                .row(&[
                    topo.name.clone(),
                    topo.num_edges().to_string(),
                    p.round.to_string(),
                    format!("{:.6}", p.sim_time),
                    format!("{:.6e}", p.error),
                ])
                .unwrap();
        }
        let b_min = scenario.min_edge_bandwidth(&topo);
        let t_conv = run.convergence_time.map(|t| t * 1e3);
        summary
            .row(&[
                topo.name.clone(),
                topo.num_edges().to_string(),
                format!("{:.4}", topo.asymptotic_convergence_factor()),
                format!("{:.3}", b_min),
                format!("{:.3}", run.iter_time * 1e3),
                t_conv.map(|t| format!("{t:.1}")).unwrap_or("-".into()),
            ])
            .unwrap();
        println!(
            "{:<26} {:>6} {:>8.4} {:>8.3} {:>12.3} {:>16}",
            topo.name,
            topo.num_edges(),
            topo.asymptotic_convergence_factor(),
            b_min,
            run.iter_time * 1e3,
            t_conv.map(|t| format!("{t:.1}")).unwrap_or("-".into()),
        );
    }
    curve.flush().unwrap();
    summary.flush().unwrap();
}

/// Fig. 1 — homogeneous bandwidth, n=16.
pub fn fig1(opts: &ExpOptions) {
    let n = 16;
    let sc = BandwidthScenario::paper_homogeneous(n);
    let mut entries = vec![
        baselines::ring(n),
        baselines::grid2d(n),
        baselines::torus2d(n),
        baselines::exponential(n),
        baselines::u_equistatic(n, 2, opts.seed),
    ];
    for r in [16usize, 24, 32, 54] {
        entries.push(ba_topo_cached(&sc, r, opts, &format!("ba_homog_n16_r{r}")));
    }
    consensus_figure("fig1", &sc, entries, opts);
}

/// Fig. 2 — node-level heterogeneity, n=16 (8×9.76 + 8×3.25 GB/s).
pub fn fig2(opts: &ExpOptions) {
    let n = 16;
    let sc = BandwidthScenario::paper_node_level();
    let mut entries = vec![
        baselines::ring(n),
        baselines::grid2d(n),
        baselines::torus2d(n),
        baselines::exponential(n),
        baselines::u_equistatic(n, 2, opts.seed),
    ];
    for r in [16usize, 32, 48] {
        entries.push(ba_topo_cached(&sc, r, opts, &format!("ba_node_n16_r{r}")));
    }
    consensus_figure("fig2", &sc, entries, opts);
}

/// Fig. 4 — intra-server link heterogeneity, n=8 (Fig. 3 server).
pub fn fig4(opts: &ExpOptions) {
    let n = 8;
    let sc = BandwidthScenario::paper_intra_server();
    let mut entries = vec![
        baselines::ring(n),
        baselines::grid2d(n),
        baselines::torus2d(n),
        baselines::exponential(n),
    ];
    for r in [8usize, 12, 16] {
        entries.push(ba_topo_cached(&sc, r, opts, &format!("ba_intra_n8_r{r}")));
    }
    consensus_figure("fig4", &sc, entries, opts);
}

/// Fig. 6 — inter-server switch-port heterogeneity, BCube(4,2), n=16.
pub fn fig6(opts: &ExpOptions) {
    let n = 16;
    let sc = BandwidthScenario::paper_inter_server();
    let mut entries = vec![
        baselines::ring(n),
        baselines::grid2d(n),
        baselines::torus2d(n),
        baselines::exponential(n),
        baselines::u_equistatic(n, 2, opts.seed),
    ];
    for r in [24usize, 48] {
        entries.push(ba_topo_cached(&sc, r, opts, &format!("ba_inter_n16_r{r}")));
    }
    consensus_figure("fig6", &sc, entries, opts);
}

// ---------------------------------------------------------------------------
// Table I — scalability
// ---------------------------------------------------------------------------

/// Table I: asymptotic convergence factor + convergence time (to 1e-4) vs n,
/// for exponential / U-EquiStatic / BA-Topo at matched sparsity (BA degree
/// sum = half the exponential graph's total degree sum, i.e. r = n·⌈log₂n⌉/2).
pub fn table1(opts: &ExpOptions) {
    // The n ∈ {96, 128} rows take tens of minutes of ADMM + O(n³) spectral
    // polish; enable them explicitly with BATOPO_TABLE1_HUGE=1.
    let huge = std::env::var("BATOPO_TABLE1_HUGE").map(|v| v == "1").unwrap_or(false);
    let mut sizes: Vec<usize> = if opts.quick {
        vec![4, 6, 8, 12, 16, 24, 32]
    } else {
        vec![4, 6, 8, 12, 16, 24, 32, 48, 64]
    };
    if huge {
        sizes.extend([96, 128]);
    }
    let tm = TimeModel::default();
    let cfg = ConsensusConfig {
        eps: 1e-4,
        max_rounds: 20_000,
        seed: opts.seed,
        dim: 64,
        ..Default::default()
    };
    let mut csv = CsvWriter::create(
        opts.out_dir.join("table1.csv"),
        &["n", "topology", "edges", "r_asym", "conv_time_ms"],
    )
    .expect("csv");

    println!("── Table I: scalability (homogeneous) ──");
    println!(
        "{:>4} | {:<24} {:>6} {:>8} {:>14}",
        "n", "topology", "edges", "r_asym", "conv time (ms)"
    );
    for &n in &sizes {
        let sc = BandwidthScenario::paper_homogeneous(n);
        let d = (n as f64).log2().ceil() as usize;
        let r_ba = (n * d / 2).max(n - 1);
        let m_equi = (d / 2).max(1).min(n / 2);
        let mut row_entries: Vec<Topology> = vec![
            baselines::exponential(n),
            baselines::u_equistatic(n, m_equi, opts.seed),
        ];
        row_entries.push(ba_topo_cached(&sc, r_ba, opts, &format!("ba_homog_n{n}_r{r_ba}")));
        for topo in row_entries {
            let run = run_consensus(None, &topo, &sc, &tm, &cfg).expect("consensus");
            let t_conv = run.convergence_time.map(|t| t * 1e3);
            csv.row(&[
                n.to_string(),
                topo.name.clone(),
                topo.num_edges().to_string(),
                format!("{:.4}", topo.asymptotic_convergence_factor()),
                t_conv.map(|t| format!("{t:.1}")).unwrap_or("-".into()),
            ])
            .unwrap();
            println!(
                "{:>4} | {:<24} {:>6} {:>8.4} {:>14}",
                n,
                topo.name,
                topo.num_edges(),
                topo.asymptotic_convergence_factor(),
                t_conv.map(|t| format!("{t:.1}")).unwrap_or("-".into()),
            );
        }
    }
    csv.flush().unwrap();
}

// ---------------------------------------------------------------------------
// DSGD — Figs. 7–10 + Table II
// ---------------------------------------------------------------------------

/// One DSGD scenario sweep: (figure name, scenario, topology entries).
fn dsgd_entries(
    fig: &str,
    opts: &ExpOptions,
) -> (BandwidthScenario, Vec<Topology>) {
    match fig {
        "fig7" => {
            let sc = BandwidthScenario::paper_homogeneous(16);
            let mut v = baseline_set(16, opts, true);
            for r in [16usize, 24, 32, 54] {
                v.push(ba_topo_cached(&sc, r, opts, &format!("ba_homog_n16_r{r}")));
            }
            (sc, v)
        }
        "fig8" => {
            let sc = BandwidthScenario::paper_node_level();
            let mut v = baseline_set(16, opts, true);
            for r in [16usize, 32, 48] {
                v.push(ba_topo_cached(&sc, r, opts, &format!("ba_node_n16_r{r}")));
            }
            (sc, v)
        }
        "fig9" => {
            let sc = BandwidthScenario::paper_intra_server();
            let mut v = baseline_set(8, opts, false);
            for r in [8usize, 12, 16] {
                v.push(ba_topo_cached(&sc, r, opts, &format!("ba_intra_n8_r{r}")));
            }
            (sc, v)
        }
        "fig10" => {
            let sc = BandwidthScenario::paper_inter_server();
            let mut v = baseline_set(16, opts, true);
            for r in [24usize, 48] {
                v.push(ba_topo_cached(&sc, r, opts, &format!("ba_inter_n16_r{r}")));
            }
            (sc, v)
        }
        other => panic!("unknown dsgd figure {other}"),
    }
}

fn baseline_set(n: usize, opts: &ExpOptions, with_equi: bool) -> Vec<Topology> {
    let mut v = vec![
        Baseline::Ring.build(n, opts.seed),
        Baseline::Grid2d.build(n, opts.seed),
        Baseline::Torus2d.build(n, opts.seed),
        Baseline::Exponential.build(n, opts.seed),
    ];
    if with_equi {
        v.push(Baseline::UEquiStatic { m: 2 }.build(n, opts.seed));
        v.push(Baseline::UEquiStatic { m: 3 }.build(n, opts.seed));
    }
    v
}

/// Run one DSGD figure (accuracy-vs-time curves) for one dataset config, and
/// append its time-to-target rows to the Table II collector.
fn dsgd_figure(
    engine: &PjRtEngine,
    fig: &str,
    model: &str,
    target: f64,
    opts: &ExpOptions,
    table2: &mut CsvWriter,
) {
    let (scenario, entries) = dsgd_entries(fig, opts);
    let mut curve = CsvWriter::create(
        opts.out_dir.join(format!("{fig}_{model}.csv")),
        &[
            "topology", "edges", "epoch", "sim_time_s", "train_loss", "eval_loss", "eval_acc",
        ],
    )
    .expect("csv");

    println!(
        "── {fig} ({model}): DSGD under {} bandwidth, target acc {target} ──",
        scenario.name()
    );
    println!(
        "{:<26} {:>6} {:>12} {:>10} {:>16}",
        "topology", "edges", "t_iter(ms)", "final acc", "t(acc≥tgt) s"
    );
    for topo in entries {
        let mut cfg = DsgdConfig::new(model);
        cfg.seed = opts.seed;
        cfg.target_accuracy = Some(target);
        cfg.epochs = if opts.quick { 4 } else { 16 };
        cfg.mix_variant = MixVariant::Native;
        if opts.quick {
            let runner_cfg = engine.manifest().configs.get(model).expect("config");
            let mut spec = crate::training::data::DatasetSpec::for_config(runner_cfg);
            spec.train_per_class = 8;
            cfg.dataset = Some(spec);
        }
        let trainer = DsgdTrainer::new(engine, scenario.clone(), cfg);
        let out = trainer.run(&topo).expect("dsgd run");
        for r in &out.records {
            curve
                .row(&[
                    topo.name.clone(),
                    topo.num_edges().to_string(),
                    r.epoch.to_string(),
                    format!("{:.4}", r.sim_time),
                    format!("{:.5}", r.train_loss),
                    format!("{:.5}", r.eval_loss),
                    format!("{:.5}", r.eval_acc),
                ])
                .unwrap();
        }
        let ttt = out.time_to_target;
        table2
            .row(&[
                model.to_string(),
                scenario.name().to_string(),
                topo.name.clone(),
                topo.num_edges().to_string(),
                format!("{:.2}", target),
                ttt.map(|t| format!("{t:.2}")).unwrap_or("-".into()),
                format!("{:.4}", out.final_accuracy),
            ])
            .unwrap();
        println!(
            "{:<26} {:>6} {:>12.3} {:>10.4} {:>16}",
            topo.name,
            topo.num_edges(),
            out.iter_time * 1e3,
            out.final_accuracy,
            ttt.map(|t| format!("{t:.2}")).unwrap_or("-".into()),
        );
    }
    curve.flush().unwrap();
}

/// Table II (plus Figs. 7–10 curves): DSGD time-to-target-accuracy across the
/// four bandwidth scenarios and both synthetic datasets.
pub fn table2(opts: &ExpOptions) {
    let engine = PjRtEngine::from_artifacts()
        .expect("PJRT engine (run `make artifacts` first)");
    let mut t2 = CsvWriter::create(
        opts.out_dir.join("table2.csv"),
        &[
            "dataset", "scenario", "topology", "edges", "target_acc", "time_to_target_s",
            "final_acc",
        ],
    )
    .expect("csv");
    // Targets chosen (like the paper's 84%/62%) to be reachable by every
    // topology on the synthetic tasks; see EXPERIMENTS.md.
    let specs: Vec<(&str, &str, f64)> = if opts.quick {
        vec![
            ("fig7", "tiny", 0.75),
            ("fig8", "tiny", 0.75),
            ("fig9", "tiny", 0.75),
            ("fig10", "tiny", 0.75),
            ("fig7", "tiny100", 0.22),
            ("fig8", "tiny100", 0.22),
            ("fig9", "tiny100", 0.22),
            ("fig10", "tiny100", 0.22),
        ]
    } else {
        vec![
            ("fig7", "tiny", 0.90),
            ("fig8", "tiny", 0.90),
            ("fig9", "tiny", 0.90),
            ("fig10", "tiny", 0.90),
            ("fig7", "tiny100", 0.25),
            ("fig8", "tiny100", 0.25),
            ("fig9", "tiny100", 0.25),
            ("fig10", "tiny100", 0.25),
        ]
    };
    for (fig, model, target) in specs {
        dsgd_figure(&engine, fig, model, target, opts, &mut t2);
    }
    t2.flush().unwrap();
    println!("table2.csv written to {}", opts.out_dir.display());
}

/// Single DSGD figure entrypoints (tiny dataset).
pub fn fig7(opts: &ExpOptions) {
    single_fig("fig7", opts);
}
pub fn fig8(opts: &ExpOptions) {
    single_fig("fig8", opts);
}
pub fn fig9(opts: &ExpOptions) {
    single_fig("fig9", opts);
}
pub fn fig10(opts: &ExpOptions) {
    single_fig("fig10", opts);
}

fn single_fig(fig: &str, opts: &ExpOptions) {
    let engine = PjRtEngine::from_artifacts()
        .expect("PJRT engine (run `make artifacts` first)");
    let mut t2 = CsvWriter::create(
        opts.out_dir.join(format!("{fig}_rows.csv")),
        &[
            "dataset", "scenario", "topology", "edges", "target_acc", "time_to_target_s",
            "final_acc",
        ],
    )
    .expect("csv");
    let target = if opts.quick { 0.55 } else { 0.75 };
    dsgd_figure(&engine, fig, "tiny", target, opts, &mut t2);
    t2.flush().unwrap();
}

/// Dispatch by name.
pub fn run(names: &[String], opts: &ExpOptions) {
    std::fs::create_dir_all(&opts.out_dir).expect("results dir");
    let all = names.iter().any(|n| n == "all");
    let want = |n: &str| all || names.iter().any(|x| x == n);
    if want("fig1") {
        fig1(opts);
    }
    if want("fig2") {
        fig2(opts);
    }
    if want("fig4") {
        fig4(opts);
    }
    if want("fig6") {
        fig6(opts);
    }
    if want("table1") {
        table1(opts);
    }
    if want("table2") {
        table2(opts);
    } else {
        for f in ["fig7", "fig8", "fig9", "fig10"] {
            if want(f) {
                single_fig(f, opts);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ba_spec_budgets_scale() {
        let s_small = ba_spec(BandwidthScenario::paper_homogeneous(8), 12, false);
        let s_big = ba_spec(BandwidthScenario::paper_homogeneous(128), 448, false);
        assert!(s_big.max_iters <= s_small.max_iters);
        assert!(s_big.polish_swaps <= s_small.polish_swaps);
        let q = ba_spec(BandwidthScenario::paper_homogeneous(16), 32, true);
        assert!(q.max_iters <= 60);
    }

    #[test]
    fn topo_cache_roundtrip() {
        let dir = std::env::temp_dir().join("batopo_exp_cache_test");
        std::fs::remove_dir_all(&dir).ok();
        let opts = ExpOptions {
            quick: true,
            out_dir: dir.clone(),
            seed: 3,
        };
        let sc = BandwidthScenario::paper_homogeneous(8);
        let t1 = ba_topo_cached(&sc, 12, &opts, "test_n8_r12");
        let t2 = ba_topo_cached(&sc, 12, &opts, "test_n8_r12"); // cached path
        assert_eq!(t1.graph.edges(), t2.graph.edges());
        assert!(dir.join("topos/test_n8_r12.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
