//! Bench harness (criterion is unavailable offline, so we ship our own):
//! warmup + timed iterations with mean/median/stddev reporting, plus the
//! experiment drivers that regenerate every table and figure of the paper
//! ([`experiments`]) and the performance micro-benches ([`perf`]).

pub mod ablations;
pub mod experiments;
pub mod perf;
pub mod records;

use std::time::Instant;

/// Summary statistics over timed iterations (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Mean iteration time (s).
    pub mean: f64,
    /// Median iteration time (s).
    pub median: f64,
    /// 95th-percentile iteration time (s).
    pub p95: f64,
    /// Population standard deviation (s).
    pub stddev: f64,
    /// Fastest iteration (s).
    pub min: f64,
    /// Slowest iteration (s).
    pub max: f64,
}

impl BenchStats {
    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} ± {:>9}  (median {:>10}, min {:>10}, n={})",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.stddev),
            fmt_time(self.median),
            fmt_time(self.min),
            self.iters
        )
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats_from(name, samples)
}

/// Build stats from raw samples.
pub fn stats_from(name: &str, mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        median: samples[n / 2],
        p95: percentile(&samples, 0.95),
        stddev: var.sqrt(),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Nearest-rank percentile of an ascending-sorted sample vector, `q ∈ [0, 1]`.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_computed_correctly() {
        let s = stats_from("t", vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p95, 5.0);
        assert!((s.stddev - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.5), 50.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.95), 7.0);
    }

    #[test]
    fn time_fn_measures_something() {
        let mut acc = 0u64;
        let s = time_fn("spin", 1, 5, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.median && s.median <= s.max);
        std::hint::black_box(acc);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
