//! Bench harness (criterion is unavailable offline, so we ship our own):
//! warmup + timed iterations with mean/median/stddev reporting, plus the
//! experiment drivers that regenerate every table and figure of the paper
//! ([`experiments`]) and the performance micro-benches ([`perf`]).

pub mod ablations;
pub mod experiments;
pub mod perf;
pub mod records;
pub mod scenario_report;

use std::time::Instant;

/// Summary statistics over timed iterations (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations behind the statistics (non-finite samples
    /// are excluded — see [`BenchStats::non_finite`]).
    pub iters: usize,
    /// Samples dropped because they were NaN/infinite. Wall-clock timers
    /// never produce these, but derived samples (throughput ratios, external
    /// measurements) can; they are flagged instead of poisoning the sort and
    /// the aggregate means the CI perf gate compares.
    pub non_finite: usize,
    /// Mean iteration time (s).
    pub mean: f64,
    /// Median iteration time (s).
    pub median: f64,
    /// 95th-percentile iteration time (s).
    pub p95: f64,
    /// Population standard deviation (s).
    pub stddev: f64,
    /// Fastest iteration (s).
    pub min: f64,
    /// Slowest iteration (s).
    pub max: f64,
}

impl BenchStats {
    /// One-line human-readable report.
    pub fn report(&self) -> String {
        let flag = if self.non_finite > 0 {
            format!("  [{} non-finite sample(s) dropped]", self.non_finite)
        } else {
            String::new()
        };
        format!(
            "{:<40} {:>10} ± {:>9}  (median {:>10}, min {:>10}, n={}){flag}",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.stddev),
            fmt_time(self.median),
            fmt_time(self.min),
            self.iters
        )
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats_from(name, samples)
}

/// Build stats from raw samples. Non-finite samples (NaN/±∞) are dropped and
/// counted in [`BenchStats::non_finite`] rather than panicking the whole
/// bench run inside the sort; with no finite samples at all the statistics
/// are zeroed (and flagged).
pub fn stats_from(name: &str, samples: Vec<f64>) -> BenchStats {
    let total = samples.len();
    let mut samples: Vec<f64> = samples.into_iter().filter(|x| x.is_finite()).collect();
    let non_finite = total - samples.len();
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    if n == 0 {
        return BenchStats {
            name: name.to_string(),
            iters: 0,
            non_finite,
            mean: 0.0,
            median: 0.0,
            p95: 0.0,
            stddev: 0.0,
            min: 0.0,
            max: 0.0,
        };
    }
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        non_finite,
        mean,
        median: samples[n / 2],
        p95: percentile(&samples, 0.95),
        stddev: var.sqrt(),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Nearest-rank percentile of an ascending-sorted sample vector, `q ∈ [0, 1]`.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_computed_correctly() {
        let s = stats_from("t", vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p95, 5.0);
        assert!((s.stddev - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.5), 50.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.95), 7.0);
    }

    #[test]
    fn time_fn_measures_something() {
        let mut acc = 0u64;
        let s = time_fn("spin", 1, 5, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.median && s.median <= s.max);
        std::hint::black_box(acc);
    }

    #[test]
    fn non_finite_samples_are_dropped_and_flagged() {
        // Regression: a NaN sample used to panic the partial_cmp sort and
        // take the whole bench run down with it.
        let s = stats_from("t", vec![1.0, f64::NAN, 3.0, f64::INFINITY, 2.0]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.non_finite, 2);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert!(s.report().contains("non-finite"));
        // All-non-finite degenerates to zeroed (flagged) stats, not a panic.
        let z = stats_from("z", vec![f64::NAN, f64::NEG_INFINITY]);
        assert_eq!(z.iters, 0);
        assert_eq!(z.non_finite, 2);
        assert_eq!(z.mean, 0.0);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
