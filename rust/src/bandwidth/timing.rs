//! The paper's time model (§VI, Eqs. 34–35).
//!
//! The paper evaluates wall time analytically from two measured constants:
//! the time to ship one model's parameters over a 9.76 GB/s link
//! (`t_comm = 5.01 ms` for ResNet-18) and the single-GPU compute time per
//! iteration (`t_comp = 15.21 ms` on a 2080 Ti). Slower links scale the
//! communication term by `b_avail / b_min`:
//!
//! - Eq. 34: `t_iter  = (b_avail / b_min) · t_comm`
//! - Eq. 35: `t_epoch = ((b_avail / b_min) · t_comm + t_comp) · c_iter`
//!
//! We keep the identical model (with the identical constants by default) so
//! every reported time axis follows the paper's methodology.

use super::scenarios::BandwidthScenario;
use crate::graph::Topology;

/// Measured-constant time model.
#[derive(Debug, Clone)]
pub struct TimeModel {
    /// Reference bandwidth the constants were measured at (GB/s).
    pub b_avail: f64,
    /// Time to communicate one parameter set at `b_avail` (seconds).
    pub t_comm: f64,
    /// Compute time per training iteration (seconds).
    pub t_comp: f64,
}

impl Default for TimeModel {
    /// The paper's measured constants: 9.76 GB/s, 5.01 ms, 15.21 ms.
    fn default() -> Self {
        TimeModel {
            b_avail: 9.76,
            t_comm: 5.01e-3,
            t_comp: 15.21e-3,
        }
    }
}

impl TimeModel {
    /// Communication time of one synchronization round over the slowest edge
    /// (Eq. 34), in seconds.
    pub fn iter_comm_time(&self, scenario: &BandwidthScenario, topo: &Topology) -> f64 {
        let b_min = scenario.min_edge_bandwidth(topo);
        assert!(b_min > 0.0, "topology has a zero-bandwidth edge");
        (self.b_avail / b_min) * self.t_comm
    }

    /// Consensus-experiment iteration time — pure gossip, no compute.
    pub fn consensus_iter_time(&self, scenario: &BandwidthScenario, topo: &Topology) -> f64 {
        self.iter_comm_time(scenario, topo)
    }

    /// Training iteration time: communication + compute.
    pub fn train_iter_time(&self, scenario: &BandwidthScenario, topo: &Topology) -> f64 {
        self.iter_comm_time(scenario, topo) + self.t_comp
    }

    /// Epoch time (Eq. 35) for `c_iter` iterations per epoch.
    pub fn epoch_time(
        &self,
        scenario: &BandwidthScenario,
        topo: &Topology,
        c_iter: usize,
    ) -> f64 {
        self.train_iter_time(scenario, topo) * c_iter as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::baselines;

    #[test]
    fn ring_homogeneous_iter_time() {
        // Ring degree 2 → b_min = 9.76/2 → t_iter = 2 · 5.01ms.
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_homogeneous(16);
        let topo = baselines::ring(16);
        let t = tm.consensus_iter_time(&sc, &topo);
        assert!((t - 2.0 * 5.01e-3).abs() < 1e-12);
    }

    #[test]
    fn exponential_intra_server_penalty() {
        // §VI-A3: exponential's b_min = 0.976 GB/s → factor 10 vs b_avail.
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_intra_server();
        let topo = baselines::exponential(8);
        let t = tm.consensus_iter_time(&sc, &topo);
        assert!((t - 10.0 * 5.01e-3).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn epoch_time_composition() {
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_homogeneous(16);
        let topo = baselines::ring(16);
        let t_iter = tm.train_iter_time(&sc, &topo);
        let t_epoch = tm.epoch_time(&sc, &topo, 97);
        assert!((t_epoch - 97.0 * t_iter).abs() < 1e-12);
        assert!(t_iter > tm.t_comp);
    }

    #[test]
    fn denser_topologies_pay_more_per_iteration() {
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_homogeneous(16);
        let ring = baselines::ring(16);
        let torus = baselines::torus2d(16);
        assert!(tm.consensus_iter_time(&sc, &ring) < tm.consensus_iter_time(&sc, &torus));
    }
}
