//! The paper's time model (§VI, Eqs. 34–35).
//!
//! The paper evaluates wall time analytically from two measured constants:
//! the time to ship one model's parameters over a 9.76 GB/s link
//! (`t_comm = 5.01 ms` for ResNet-18) and the single-GPU compute time per
//! iteration (`t_comp = 15.21 ms` on a 2080 Ti). Slower links scale the
//! communication term by `b_avail / b_min`:
//!
//! - Eq. 34: `t_iter  = (b_avail / b_min) · t_comm`
//! - Eq. 35: `t_epoch = ((b_avail / b_min) · t_comm + t_comp) · c_iter`
//!
//! We keep the identical model (with the identical constants by default) so
//! every reported time axis follows the paper's methodology.
//!
//! A topology whose slowest edge has zero (or negative/non-finite) available
//! bandwidth has no finite round time; the model reports that as a
//! [`TimingError`] instead of panicking, so scripted `link_degrade` /
//! `node_churn` scenarios that drive an edge to zero can be handled by the
//! caller (the dynamic simulator treats such a phase as "no gossip possible"
//! — see [`crate::bandwidth::dynamic`]).

use super::scenarios::BandwidthScenario;
use crate::graph::Topology;

/// Failure of the analytic time model.
#[derive(Debug, Clone, PartialEq)]
pub enum TimingError {
    /// The topology's minimum available edge bandwidth is not a positive
    /// finite number — Eq. 34's `b_avail / b_min` is undefined.
    NonPositiveBandwidth {
        /// The offending `b_min` (GB/s).
        b_min: f64,
    },
}

impl std::fmt::Display for TimingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TimingError::NonPositiveBandwidth { b_min } => write!(
                f,
                "topology has an edge with non-positive available bandwidth \
                 (b_min = {b_min} GB/s); Eq. 34 round time is undefined"
            ),
        }
    }
}

impl std::error::Error for TimingError {}

/// Measured-constant time model.
#[derive(Debug, Clone)]
pub struct TimeModel {
    /// Reference bandwidth the constants were measured at (GB/s).
    pub b_avail: f64,
    /// Time to communicate one parameter set at `b_avail` (seconds).
    pub t_comm: f64,
    /// Compute time per training iteration (seconds).
    pub t_comp: f64,
}

impl Default for TimeModel {
    /// The paper's measured constants: 9.76 GB/s, 5.01 ms, 15.21 ms.
    fn default() -> Self {
        TimeModel {
            b_avail: 9.76,
            t_comm: 5.01e-3,
            t_comp: 15.21e-3,
        }
    }
}

impl TimeModel {
    /// Communication time of one synchronization round over the slowest edge
    /// (Eq. 34), in seconds. Errors when the slowest edge has no positive
    /// finite bandwidth (a scripted outage).
    pub fn iter_comm_time(
        &self,
        scenario: &BandwidthScenario,
        topo: &Topology,
    ) -> Result<f64, TimingError> {
        let b_min = scenario.min_edge_bandwidth(topo);
        if !(b_min > 0.0 && b_min.is_finite()) {
            return Err(TimingError::NonPositiveBandwidth { b_min });
        }
        Ok((self.b_avail / b_min) * self.t_comm)
    }

    /// Consensus-experiment iteration time — pure gossip, no compute.
    pub fn consensus_iter_time(
        &self,
        scenario: &BandwidthScenario,
        topo: &Topology,
    ) -> Result<f64, TimingError> {
        self.iter_comm_time(scenario, topo)
    }

    /// Training iteration time: communication + compute.
    pub fn train_iter_time(
        &self,
        scenario: &BandwidthScenario,
        topo: &Topology,
    ) -> Result<f64, TimingError> {
        Ok(self.iter_comm_time(scenario, topo)? + self.t_comp)
    }

    /// Epoch time (Eq. 35) for `c_iter` iterations per epoch.
    pub fn epoch_time(
        &self,
        scenario: &BandwidthScenario,
        topo: &Topology,
        c_iter: usize,
    ) -> Result<f64, TimingError> {
        Ok(self.train_iter_time(scenario, topo)? * c_iter as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::baselines;

    #[test]
    fn ring_homogeneous_iter_time() {
        // Ring degree 2 → b_min = 9.76/2 → t_iter = 2 · 5.01ms.
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_homogeneous(16);
        let topo = baselines::ring(16);
        let t = tm.consensus_iter_time(&sc, &topo).unwrap();
        assert!((t - 2.0 * 5.01e-3).abs() < 1e-12);
    }

    #[test]
    fn exponential_intra_server_penalty() {
        // §VI-A3: exponential's b_min = 0.976 GB/s → factor 10 vs b_avail.
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_intra_server();
        let topo = baselines::exponential(8);
        let t = tm.consensus_iter_time(&sc, &topo).unwrap();
        assert!((t - 10.0 * 5.01e-3).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn epoch_time_composition() {
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_homogeneous(16);
        let topo = baselines::ring(16);
        let t_iter = tm.train_iter_time(&sc, &topo).unwrap();
        let t_epoch = tm.epoch_time(&sc, &topo, 97).unwrap();
        assert!((t_epoch - 97.0 * t_iter).abs() < 1e-12);
        assert!(t_iter > tm.t_comp);
    }

    #[test]
    fn denser_topologies_pay_more_per_iteration() {
        let tm = TimeModel::default();
        let sc = BandwidthScenario::paper_homogeneous(16);
        let ring = baselines::ring(16);
        let torus = baselines::torus2d(16);
        assert!(
            tm.consensus_iter_time(&sc, &ring).unwrap()
                < tm.consensus_iter_time(&sc, &torus).unwrap()
        );
    }

    #[test]
    fn zero_bandwidth_edge_is_an_error_not_a_panic() {
        // Regression: a scripted link_degrade/node_churn scenario can drive a
        // node to exactly zero bandwidth; every time-model entry point must
        // report that as a TimingError instead of panicking.
        let tm = TimeModel::default();
        let mut bw = vec![9.76; 8];
        bw[3] = 0.0;
        let sc = BandwidthScenario::NodeLevel { bw };
        let topo = baselines::ring(8);
        for r in [
            tm.iter_comm_time(&sc, &topo),
            tm.consensus_iter_time(&sc, &topo),
            tm.train_iter_time(&sc, &topo),
            tm.epoch_time(&sc, &topo, 10),
        ] {
            match r {
                Err(TimingError::NonPositiveBandwidth { b_min }) => assert_eq!(b_min, 0.0),
                other => panic!("expected NonPositiveBandwidth, got {other:?}"),
            }
        }
    }
}
