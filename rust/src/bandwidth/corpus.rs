//! The adversarial scenario corpus: named, seeded [`ScenarioBuilder`]
//! programs covering the failure modes ROADMAP item 3 asks for — heavy-tailed
//! (Pareto / log-normal) and correlated bandwidth draws, partitions that
//! heal, coordinated stragglers, zonal outages and diurnal load curves — plus
//! [`ScenarioProgram`], the *replayable* value form of a DSL program that the
//! scenario fuzzer ([`crate::bandwidth::fuzz`]) generates, shrinks and dumps
//! to disk.
//!
//! A [`ScenarioProgram`] is to a [`ScenarioBuilder`] what an AST is to a
//! builder call chain: a plain data value that can be compared, mutated
//! (shrunk move-by-move), serialized with [`ScenarioProgram::dump`] and read
//! back with [`ScenarioProgram::parse`]. `reproduce dynamic` sweeps
//! [`corpus`] and renders one markdown analysis report per entry; `batopo
//! fuzz scenarios` minimizes invariant-violating random programs into
//! `*.scenario` dumps replayable with `batopo fuzz replay`.

use crate::bandwidth::scenario_dsl::{
    CompiledScenario, ScenarioBuilder, ScenarioEvent, ScheduledEvent, TailDist,
};
use crate::util::rng::Xoshiro256pp;
use std::fmt::Write as _;

/// A scenario DSL program as a plain (comparable, serializable) value.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioProgram {
    /// Per-node initial bandwidths (GB/s).
    pub initial: Vec<f64>,
    /// Scenario horizon in phases.
    pub phases: usize,
    /// Simulated seconds per phase.
    pub phase_seconds: f64,
    /// Bandwidth clamp `[lo, hi]` applied to every update.
    pub clamp: (f64, f64),
    /// Bandwidth of departed/partitioned nodes (GB/s).
    pub churn_floor: f64,
    /// Seed for the stochastic events (drift, heavy-tailed draws) *and* the
    /// consensus simulation replaying this program.
    pub seed: u64,
    /// The event schedule.
    pub events: Vec<ScheduledEvent>,
}

impl ScenarioProgram {
    /// Materialize the program as a validated [`ScenarioBuilder`].
    pub fn builder(&self) -> ScenarioBuilder {
        let mut b = ScenarioBuilder::new(self.initial.clone())
            .phases(self.phases)
            .phase_seconds(self.phase_seconds)
            .clamp(self.clamp.0, self.clamp.1)
            .churn_floor(self.churn_floor);
        for ev in &self.events {
            b = b.event(ev.phase, ev.event.clone());
        }
        b
    }

    /// Compile with the program's own seed.
    pub fn compile(&self) -> CompiledScenario {
        self.builder().compile(self.seed)
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.initial.len()
    }

    /// Serialize to the line-oriented `*.scenario` dump format (see
    /// `docs/SCENARIOS.md`). `parse(dump())` round-trips exactly: floats are
    /// written with Rust's shortest round-trip representation.
    pub fn dump(&self) -> String {
        let mut s = String::from("# batopo scenario dump v1\n");
        let _ = writeln!(s, "phases {}", self.phases);
        let _ = writeln!(s, "phase_seconds {}", self.phase_seconds);
        let _ = writeln!(s, "clamp {} {}", self.clamp.0, self.clamp.1);
        let _ = writeln!(s, "churn_floor {}", self.churn_floor);
        let _ = writeln!(s, "seed {}", self.seed);
        let init: Vec<String> = self.initial.iter().map(|b| b.to_string()).collect();
        let _ = writeln!(s, "init {}", init.join(" "));
        for ev in &self.events {
            let _ = writeln!(s, "event {} {}", ev.phase, event_words(&ev.event));
        }
        s
    }

    /// Parse a `*.scenario` dump (inverse of [`dump`]; `#` lines and blank
    /// lines are ignored, so dumps may carry commentary).
    ///
    /// [`dump`]: ScenarioProgram::dump
    pub fn parse(text: &str) -> Result<ScenarioProgram, String> {
        let mut initial: Option<Vec<f64>> = None;
        let mut phases: Option<usize> = None;
        let mut phase_seconds = 1.0f64;
        let mut clamp = (1e-3, f64::INFINITY);
        let mut churn_floor = 0.05f64;
        let mut seed = 0u64;
        let mut events = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let at = |m: String| format!("line {}: {m}", idx + 1);
            let mut toks = line.split_whitespace();
            let key = toks.next().unwrap_or_default();
            match key {
                "phases" => phases = Some(parse_num(toks.next(), "phases").map_err(at)?),
                "phase_seconds" => {
                    phase_seconds = parse_num(toks.next(), "phase_seconds").map_err(at)?;
                }
                "clamp" => {
                    clamp = (
                        parse_num(toks.next(), "clamp lo").map_err(&at)?,
                        parse_num(toks.next(), "clamp hi").map_err(&at)?,
                    );
                }
                "churn_floor" => {
                    churn_floor = parse_num(toks.next(), "churn_floor").map_err(at)?;
                }
                "seed" => seed = parse_num(toks.next(), "seed").map_err(at)?,
                "init" => {
                    let bw: Result<Vec<f64>, String> =
                        toks.map(|t| parse_num(Some(t), "init value")).collect();
                    initial = Some(bw.map_err(at)?);
                }
                "event" => {
                    // Keep the raw remainder so report labels retain spaces.
                    let mut parts = line.splitn(4, char::is_whitespace);
                    parts.next(); // "event"
                    let phase: usize = parse_num(parts.next(), "event phase").map_err(&at)?;
                    let Some(kind) = parts.next() else {
                        return Err(at("event needs a kind".to_string()));
                    };
                    let rest = parts.next().unwrap_or("");
                    let event = parse_event(kind, rest).map_err(at)?;
                    events.push(ScheduledEvent { phase, event });
                }
                other => return Err(at(format!("unknown directive {other:?}"))),
            }
        }
        let initial = initial.ok_or("missing `init` line")?;
        if initial.is_empty() {
            return Err("`init` needs at least one node".to_string());
        }
        let phases = phases.ok_or("missing `phases` line")?;
        Ok(ScenarioProgram {
            initial,
            phases,
            phase_seconds,
            clamp,
            churn_floor,
            seed,
            events,
        })
    }

    /// Generate a random program (the fuzzer's case generator): 4–8 nodes,
    /// a handful of random adversarial events, plus one `report_stats`
    /// checkpoint per phase so the per-phase invariants have something to
    /// bite on.
    pub fn random(rng: &mut Xoshiro256pp, quick: bool) -> ScenarioProgram {
        let n = 4 + rng.index(5);
        let phases = if quick { 3 + rng.index(3) } else { 4 + rng.index(5) };
        let initial: Vec<f64> = (0..n).map(|_| 2.0 + 10.0 * rng.next_f64()).collect();
        let mut events = Vec::new();
        let n_events = 1 + rng.index(5);
        for _ in 0..n_events {
            let phase = rng.index(phases);
            let event = random_event(rng, n);
            // Half of the partition/straggle episodes get a matching heal at
            // a later phase, so healed and unhealed episodes both occur.
            let heal_nodes = match &event {
                ScenarioEvent::Partition { nodes } | ScenarioEvent::Straggle { nodes, .. } => {
                    Some(nodes.clone())
                }
                _ => None,
            };
            events.push(ScheduledEvent { phase, event });
            if let Some(nodes) = heal_nodes {
                if phase + 1 < phases && rng.next_f64() < 0.5 {
                    let heal_phase = phase + 1 + rng.index(phases - phase - 1);
                    events.push(ScheduledEvent {
                        phase: heal_phase,
                        event: ScenarioEvent::Heal { nodes },
                    });
                }
            }
        }
        for k in 0..phases {
            events.push(ScheduledEvent {
                phase: k,
                event: ScenarioEvent::ReportStats {
                    label: format!("phase {k}"),
                },
            });
        }
        ScenarioProgram {
            initial,
            phases,
            phase_seconds: 1.5,
            clamp: (1e-3, 1e4),
            churn_floor: 0.05,
            seed: rng.next_u64(),
            events,
        }
    }

    /// Shrinking size measure: event count dominates, then horizon length,
    /// then event magnitudes — so the greedy shrinker prefers deleting
    /// events, then shortening the scenario, then softening what remains.
    pub fn size(&self) -> f64 {
        let mut s = 1000.0 * self.events.len() as f64 + 10.0 * self.phases as f64;
        for ev in &self.events {
            s += match &ev.event {
                ScenarioEvent::Drift { sigma } => *sigma,
                ScenarioEvent::CorrelatedDrift { sigma, .. } => *sigma,
                ScenarioEvent::LinkDegrade { nodes, factor }
                | ScenarioEvent::Straggle { nodes, factor } => {
                    (1.0 - factor).abs() + 0.1 * nodes.len() as f64
                }
                ScenarioEvent::Partition { nodes } | ScenarioEvent::Heal { nodes } => {
                    0.1 * nodes.len() as f64
                }
                ScenarioEvent::Diurnal { amplitude, .. } => *amplitude,
                _ => 0.0,
            };
        }
        s
    }

    /// One greedy-shrinking step: every candidate reduction of this program
    /// (shorten the horizon, delete an event, soften an event's magnitude or
    /// halve its node set). Feed to [`crate::util::prop::shrink_greedy`] with
    /// [`size`] as the measure.
    ///
    /// [`size`]: ScenarioProgram::size
    pub fn shrink_moves(&self) -> Vec<ScenarioProgram> {
        let mut out = Vec::new();
        // Shorten the horizon (halve, then minus one), dropping orphans.
        for np in [self.phases / 2, self.phases.saturating_sub(1)] {
            if np >= 1 && np < self.phases {
                let mut p = self.clone();
                p.phases = np;
                p.events.retain(|e| e.phase < np);
                out.push(p);
            }
        }
        // Delete each event.
        for i in 0..self.events.len() {
            let mut p = self.clone();
            p.events.remove(i);
            out.push(p);
        }
        // Soften each event (halve magnitudes / node sets).
        for i in 0..self.events.len() {
            for softer in soften(&self.events[i].event) {
                let mut p = self.clone();
                p.events[i].event = softer;
                out.push(p);
            }
        }
        out
    }
}

/// Random adversarial event over `n` nodes (no `ReportStats` — checkpoints
/// are scheduled systematically by [`ScenarioProgram::random`]).
fn random_event(rng: &mut Xoshiro256pp, n: usize) -> ScenarioEvent {
    match rng.index(10) {
        0 => ScenarioEvent::Drift {
            sigma: 0.05 + 0.4 * rng.next_f64(),
        },
        1 => ScenarioEvent::SetBandwidth {
            node: rng.index(n),
            bw: 0.5 + 10.0 * rng.next_f64(),
        },
        2 => ScenarioEvent::LinkDegrade {
            nodes: random_nodes(rng, n),
            factor: 0.05 + 0.9 * rng.next_f64(),
        },
        3 => ScenarioEvent::NodeChurn {
            node: rng.index(n),
            rejoin_bw: if rng.next_f64() < 0.5 {
                None
            } else {
                Some(1.0 + 9.0 * rng.next_f64())
            },
        },
        4 => ScenarioEvent::HeavyTailDraw {
            dist: TailDist::Pareto {
                alpha: 1.1 + rng.next_f64(),
                xm: 1.0 + 3.0 * rng.next_f64(),
            },
        },
        5 => ScenarioEvent::HeavyTailDraw {
            dist: TailDist::LogNormal {
                mu: 1.0 + rng.next_f64(),
                sigma: 0.3 + 0.7 * rng.next_f64(),
            },
        },
        6 => ScenarioEvent::CorrelatedDrift {
            sigma: 0.05 + 0.3 * rng.next_f64(),
            rho: rng.next_f64(),
        },
        7 => ScenarioEvent::Partition {
            nodes: random_nodes(rng, n),
        },
        8 => ScenarioEvent::Straggle {
            nodes: random_nodes(rng, n),
            factor: 0.02 + 0.3 * rng.next_f64(),
        },
        _ => ScenarioEvent::Diurnal {
            amplitude: 0.2 + 0.7 * rng.next_f64(),
            period: 2 + rng.index(5),
        },
    }
}

fn random_nodes(rng: &mut Xoshiro256pp, n: usize) -> Vec<usize> {
    let k = 1 + rng.index(n);
    let mut v = rng.sample_indices(n, k);
    v.sort_unstable();
    v
}

/// Magnitude-halving / node-set-halving reductions of one event.
fn soften(event: &ScenarioEvent) -> Vec<ScenarioEvent> {
    let mut out = Vec::new();
    let half_nodes = |nodes: &Vec<usize>| -> Option<Vec<usize>> {
        (nodes.len() >= 2).then(|| nodes[..nodes.len() / 2].to_vec())
    };
    match event {
        ScenarioEvent::Drift { sigma } => {
            if *sigma > 1e-3 {
                out.push(ScenarioEvent::Drift { sigma: sigma / 2.0 });
            }
        }
        ScenarioEvent::CorrelatedDrift { sigma, rho } => {
            if *sigma > 1e-3 {
                out.push(ScenarioEvent::CorrelatedDrift {
                    sigma: sigma / 2.0,
                    rho: *rho,
                });
            }
        }
        ScenarioEvent::LinkDegrade { nodes, factor } => {
            if (factor - 1.0).abs() > 1e-3 {
                out.push(ScenarioEvent::LinkDegrade {
                    nodes: nodes.clone(),
                    factor: (1.0 + factor) / 2.0,
                });
            }
            if let Some(h) = half_nodes(nodes) {
                out.push(ScenarioEvent::LinkDegrade {
                    nodes: h,
                    factor: *factor,
                });
            }
        }
        ScenarioEvent::Straggle { nodes, factor } => {
            if (factor - 1.0).abs() > 1e-3 {
                out.push(ScenarioEvent::Straggle {
                    nodes: nodes.clone(),
                    factor: (1.0 + factor) / 2.0,
                });
            }
            if let Some(h) = half_nodes(nodes) {
                out.push(ScenarioEvent::Straggle {
                    nodes: h,
                    factor: *factor,
                });
            }
        }
        ScenarioEvent::Partition { nodes } => {
            if let Some(h) = half_nodes(nodes) {
                out.push(ScenarioEvent::Partition { nodes: h });
            }
        }
        ScenarioEvent::Heal { nodes } => {
            if let Some(h) = half_nodes(nodes) {
                out.push(ScenarioEvent::Heal { nodes: h });
            }
        }
        ScenarioEvent::Diurnal { amplitude, period } => {
            if *amplitude > 1e-3 {
                out.push(ScenarioEvent::Diurnal {
                    amplitude: amplitude / 2.0,
                    period: *period,
                });
            }
        }
        _ => {}
    }
    out
}

/// Serialize one event into its `.scenario` word form (the inverse of
/// [`parse_event`]): `drift 0.1`, `link_degrade 0.1 4 5 6 7`, …. Shared with
/// the `batopo serve` wire protocol, whose `event` command carries exactly
/// these words.
pub fn event_words(event: &ScenarioEvent) -> String {
    let join = |nodes: &[usize]| {
        let words: Vec<String> = nodes.iter().map(|i| i.to_string()).collect();
        words.join(" ")
    };
    match event {
        ScenarioEvent::Drift { sigma } => format!("drift {sigma}"),
        ScenarioEvent::SetBandwidth { node, bw } => format!("set_bandwidth {node} {bw}"),
        ScenarioEvent::LinkDegrade { nodes, factor } => {
            format!("link_degrade {factor} {}", join(nodes))
        }
        ScenarioEvent::NodeChurn { node, rejoin_bw } => match rejoin_bw {
            Some(bw) => format!("node_churn {node} rejoin {bw}"),
            None => format!("node_churn {node} leave"),
        },
        ScenarioEvent::ReportStats { label } => format!("report_stats {label}"),
        ScenarioEvent::HeavyTailDraw { dist } => match dist {
            TailDist::Pareto { alpha, xm } => format!("pareto_draw {alpha} {xm}"),
            TailDist::LogNormal { mu, sigma } => format!("lognormal_draw {mu} {sigma}"),
        },
        ScenarioEvent::CorrelatedDrift { sigma, rho } => format!("correlated_drift {sigma} {rho}"),
        ScenarioEvent::Partition { nodes } => format!("partition {}", join(nodes)),
        ScenarioEvent::Straggle { nodes, factor } => format!("straggle {factor} {}", join(nodes)),
        ScenarioEvent::Heal { nodes } => format!("heal {}", join(nodes)),
        ScenarioEvent::Diurnal { amplitude, period } => format!("diurnal {amplitude} {period}"),
    }
}

fn parse_num<T: std::str::FromStr>(tok: Option<&str>, what: &str) -> Result<T, String> {
    let t = tok.ok_or_else(|| format!("missing {what}"))?;
    t.parse::<T>().map_err(|_| format!("bad {what}: {t:?}"))
}

fn parse_node_list(toks: &[&str], what: &str) -> Result<Vec<usize>, String> {
    if toks.is_empty() {
        return Err(format!("{what} needs at least one node"));
    }
    toks.iter().map(|t| parse_num(Some(t), "node index")).collect()
}

/// Parse one event from its `.scenario` word form: `kind` is the first word
/// (`drift`, `set_bandwidth`, …) and `rest` the raw remainder of the line
/// (`report_stats` keeps it verbatim as the label). The inverse of
/// [`event_words`]; shared with the `batopo serve` wire protocol.
pub fn parse_event(kind: &str, rest: &str) -> Result<ScenarioEvent, String> {
    let toks: Vec<&str> = rest.split_whitespace().collect();
    let ev = match kind {
        "drift" => ScenarioEvent::Drift {
            sigma: parse_num(toks.first().copied(), "drift sigma")?,
        },
        "set_bandwidth" => ScenarioEvent::SetBandwidth {
            node: parse_num(toks.first().copied(), "node")?,
            bw: parse_num(toks.get(1).copied(), "bandwidth")?,
        },
        "link_degrade" => ScenarioEvent::LinkDegrade {
            factor: parse_num(toks.first().copied(), "factor")?,
            nodes: parse_node_list(toks.get(1..).unwrap_or(&[]), "link_degrade")?,
        },
        "node_churn" => {
            let node = parse_num(toks.first().copied(), "node")?;
            match toks.get(1).copied() {
                Some("leave") => ScenarioEvent::NodeChurn {
                    node,
                    rejoin_bw: None,
                },
                Some("rejoin") => ScenarioEvent::NodeChurn {
                    node,
                    rejoin_bw: Some(parse_num(toks.get(2).copied(), "rejoin bandwidth")?),
                },
                other => return Err(format!("node_churn needs leave|rejoin, got {other:?}")),
            }
        }
        "report_stats" => ScenarioEvent::ReportStats {
            label: rest.trim().to_string(),
        },
        "pareto_draw" => ScenarioEvent::HeavyTailDraw {
            dist: TailDist::Pareto {
                alpha: parse_num(toks.first().copied(), "alpha")?,
                xm: parse_num(toks.get(1).copied(), "xm")?,
            },
        },
        "lognormal_draw" => ScenarioEvent::HeavyTailDraw {
            dist: TailDist::LogNormal {
                mu: parse_num(toks.first().copied(), "mu")?,
                sigma: parse_num(toks.get(1).copied(), "sigma")?,
            },
        },
        "correlated_drift" => ScenarioEvent::CorrelatedDrift {
            sigma: parse_num(toks.first().copied(), "sigma")?,
            rho: parse_num(toks.get(1).copied(), "rho")?,
        },
        "partition" => ScenarioEvent::Partition {
            nodes: parse_node_list(&toks, "partition")?,
        },
        "straggle" => ScenarioEvent::Straggle {
            factor: parse_num(toks.first().copied(), "factor")?,
            nodes: parse_node_list(toks.get(1..).unwrap_or(&[]), "straggle")?,
        },
        "heal" => ScenarioEvent::Heal {
            nodes: parse_node_list(&toks, "heal")?,
        },
        "diurnal" => ScenarioEvent::Diurnal {
            amplitude: parse_num(toks.first().copied(), "amplitude")?,
            period: parse_num(toks.get(1).copied(), "period")?,
        },
        other => return Err(format!("unknown event kind {other:?}")),
    };
    Ok(ev)
}

/// One corpus entry: a named program plus the hypothesis its analysis report
/// sets out to test.
#[derive(Debug, Clone)]
pub struct NamedScenario {
    /// Corpus name (stable identifier; used in artifact file names).
    pub name: String,
    /// What the scenario is expected to show (the report's `## Hypothesis`).
    pub hypothesis: String,
    /// The scenario program itself.
    pub program: ScenarioProgram,
}

/// The named adversarial corpus over `n` nodes: the four legacy scenarios
/// (drift / degrade / churn / flash-crowd) plus heavy-tailed (Pareto and
/// log-normal), correlated drift, partition-heal, coordinated stragglers,
/// zonal outage and diurnal load — 11 scenarios total. `quick` halves the
/// horizon; `seed` drives every stochastic event.
pub fn corpus(n: usize, quick: bool, seed: u64) -> Vec<NamedScenario> {
    assert!(n >= 4, "corpus scenarios need at least 4 nodes");
    let phases = if quick { 4 } else { 8 };
    let mid = phases / 2;
    let last = phases - 1;
    let fast = 9.76;
    let half: Vec<usize> = (n / 2..n).collect();
    let all: Vec<usize> = (0..n).collect();
    let zone: Vec<usize> = (0..(n / 4).max(2)).collect();
    let ev = |phase: usize, event: ScenarioEvent| ScheduledEvent { phase, event };
    let report = |phase: usize, label: &str| {
        ev(
            phase,
            ScenarioEvent::ReportStats {
                label: label.to_string(),
            },
        )
    };
    let base = |events: Vec<ScheduledEvent>| ScenarioProgram {
        initial: vec![fast; n],
        phases,
        phase_seconds: 1.5,
        clamp: (1e-3, f64::INFINITY),
        churn_floor: 0.05,
        seed,
        events,
    };
    let named = |name: &str, hypothesis: &str, program: ScenarioProgram| NamedScenario {
        name: name.to_string(),
        hypothesis: hypothesis.to_string(),
        program,
    };

    vec![
        named(
            "drift",
            "Background i.i.d. log-normal drift slowly decorrelates link quality from the \
             initial optimum; the adaptive controller should track it with occasional switches \
             and match or beat the static topology's time-to-target.",
            base(vec![
                ev(0, ScenarioEvent::Drift { sigma: 0.25 }),
                report(mid, "mid drift"),
                report(last, "end of drift"),
            ]),
        ),
        named(
            "degrade",
            "Half the fleet permanently loses 90% of its bandwidth mid-run (co-tenant \
             interference); re-optimizing should rebalance edges onto the still-fast half \
             and recover most of the lost round rate.",
            base(vec![
                ev(
                    1,
                    ScenarioEvent::LinkDegrade {
                        nodes: half.clone(),
                        factor: 0.1,
                    },
                ),
                report(1, "after degradation"),
                report(last, "end"),
            ]),
        ),
        named(
            "churn",
            "One node departs (bandwidth at the churn floor) and rejoins at the end; the \
             adaptive controller should route around the departed node instead of letting it \
             throttle b_min for the whole episode.",
            base(vec![
                ev(
                    1,
                    ScenarioEvent::NodeChurn {
                        node: n - 1,
                        rejoin_bw: None,
                    },
                ),
                report(1, "after leave"),
                ev(
                    last,
                    ScenarioEvent::NodeChurn {
                        node: n - 1,
                        rejoin_bw: Some(fast),
                    },
                ),
                report(last, "after rejoin"),
            ]),
        ),
        named(
            "flash-crowd",
            "A fleet-wide 2x slowdown under drift, recovering at the end: uniform scaling \
             leaves the *relative* bandwidth profile unchanged, so adaptation should see \
             little to gain and hysteresis should suppress thrashing.",
            base(vec![
                ev(0, ScenarioEvent::Drift { sigma: 0.05 }),
                ev(
                    1,
                    ScenarioEvent::LinkDegrade {
                        nodes: all.clone(),
                        factor: 0.5,
                    },
                ),
                report(1, "under load"),
                ev(
                    last,
                    ScenarioEvent::LinkDegrade {
                        nodes: all,
                        factor: 2.0,
                    },
                ),
                report(last, "recovered"),
            ]),
        ),
        named(
            "heavy-tailed",
            "Pareto(1.3) bandwidth redraws put most nodes far below the scale while a few are \
             extremely fast; a bandwidth-aware re-optimization should concentrate degree on \
             the fast tail, beating the static topology's time-to-target.",
            {
                let mut p = base(vec![
                    ev(
                        1,
                        ScenarioEvent::HeavyTailDraw {
                            dist: TailDist::Pareto {
                                alpha: 1.3,
                                xm: 2.0,
                            },
                        },
                    ),
                    report(1, "after first draw"),
                    ev(
                        mid,
                        ScenarioEvent::HeavyTailDraw {
                            dist: TailDist::Pareto {
                                alpha: 1.3,
                                xm: 2.0,
                            },
                        },
                    ),
                    report(mid, "after second draw"),
                    report(last, "end"),
                ]);
                p.clamp = (0.5, 40.0);
                p
            },
        ),
        named(
            "heavy-tailed-lognormal",
            "Log-normal redraws (sigma 0.9) give a right-skewed but lighter-than-Pareto \
             profile; adaptation gains should sit between the homogeneous and Pareto \
             extremes.",
            {
                let mut p = base(vec![
                    ev(
                        1,
                        ScenarioEvent::HeavyTailDraw {
                            dist: TailDist::LogNormal {
                                mu: 2.0,
                                sigma: 0.9,
                            },
                        },
                    ),
                    report(1, "after first draw"),
                    ev(
                        mid,
                        ScenarioEvent::HeavyTailDraw {
                            dist: TailDist::LogNormal {
                                mu: 2.0,
                                sigma: 0.9,
                            },
                        },
                    ),
                    report(mid, "after second draw"),
                    report(last, "end"),
                ]);
                p.clamp = (0.5, 40.0);
                p
            },
        ),
        named(
            "correlated",
            "Strongly correlated drift (rho 0.9) moves the fleet mostly in lockstep, like \
             shared-backbone congestion: the bandwidth *profile* barely changes, so the \
             adaptive controller should switch rarely — per Vogels et al. (2301.02151), \
             time-to-target rather than the spectral gap is the metric that shows this.",
            base(vec![
                ev(
                    0,
                    ScenarioEvent::CorrelatedDrift {
                        sigma: 0.25,
                        rho: 0.9,
                    },
                ),
                report(mid, "mid drift"),
                report(last, "end"),
            ]),
        ),
        named(
            "partition-heal",
            "Half the fleet is partitioned off (churn-floor bandwidth) and heals mid-run; \
             during the partition the optimizer should concentrate edges inside the healthy \
             half, and after the heal both arms should converge again.",
            base(vec![
                ev(
                    1,
                    ScenarioEvent::Partition {
                        nodes: half.clone(),
                    },
                ),
                report(1, "under partition"),
                ev(mid, ScenarioEvent::Heal { nodes: half }),
                report(mid, "after heal"),
                report(last, "end"),
            ]),
        ),
        named(
            "stragglers",
            "Two coordinated stragglers at 8% bandwidth gate b_min for every topology that \
             keeps them connected; the adaptive controller should shed their degree to 1 \
             and restore most of the round rate until they heal.",
            base(vec![
                ev(
                    1,
                    ScenarioEvent::Straggle {
                        nodes: vec![0, 1],
                        factor: 0.08,
                    },
                ),
                report(1, "stragglers active"),
                ev(mid, ScenarioEvent::Heal { nodes: vec![0, 1] }),
                report(mid, "after heal"),
                report(last, "end"),
            ]),
        ),
        named(
            "zonal-outage",
            "A whole zone (quarter of the fleet) drops to the churn floor until the end of \
             the run: an unhealed partition. The static topology's b_min collapses for the \
             duration; the adaptive one should pay one switch and isolate the zone.",
            base(vec![
                ev(
                    1,
                    ScenarioEvent::Partition {
                        nodes: zone.clone(),
                    },
                ),
                report(1, "zone down"),
                report(mid, "mid outage"),
                ev(last, ScenarioEvent::Heal { nodes: zone }),
                report(last, "zone restored"),
            ]),
        ),
        named(
            "diurnal",
            "A diurnal load curve modulates the whole fleet sinusoidally (amplitude 0.6): \
             like flash-crowd, the relative profile is constant, so the adaptive arm should \
             hold its topology and both arms should show time-to-target set by the trough \
             phases.",
            base(vec![
                ev(
                    0,
                    ScenarioEvent::Diurnal {
                        amplitude: 0.6,
                        period: (phases / 2).max(2),
                    },
                ),
                report(mid, "mid cycle"),
                report(last, "end"),
            ]),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_program() -> ScenarioProgram {
        ScenarioProgram {
            initial: vec![9.76, 3.25, 5.5],
            phases: 5,
            phase_seconds: 1.5,
            clamp: (0.5, f64::INFINITY),
            churn_floor: 0.05,
            seed: 77,
            events: vec![
                ScheduledEvent {
                    phase: 0,
                    event: ScenarioEvent::CorrelatedDrift {
                        sigma: 0.2,
                        rho: 0.7,
                    },
                },
                ScheduledEvent {
                    phase: 1,
                    event: ScenarioEvent::Partition { nodes: vec![1, 2] },
                },
                ScheduledEvent {
                    phase: 2,
                    event: ScenarioEvent::ReportStats {
                        label: "under partition".to_string(),
                    },
                },
                ScheduledEvent {
                    phase: 3,
                    event: ScenarioEvent::Heal { nodes: vec![1, 2] },
                },
                ScheduledEvent {
                    phase: 4,
                    event: ScenarioEvent::NodeChurn {
                        node: 0,
                        rejoin_bw: Some(4.0),
                    },
                },
            ],
        }
    }

    #[test]
    fn dump_parse_roundtrips_exactly() {
        let p = sample_program();
        let q = ScenarioProgram::parse(&p.dump()).expect("parse");
        assert_eq!(p, q);
        assert_eq!(p.compile().trace.phases, q.compile().trace.phases);
    }

    #[test]
    fn random_programs_roundtrip_and_compile() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        for _ in 0..20 {
            let p = ScenarioProgram::random(&mut rng, true);
            let q = ScenarioProgram::parse(&p.dump()).expect("parse");
            assert_eq!(p, q);
            let c = p.compile();
            assert_eq!(c.num_phases(), p.phases);
            assert!(c.trace.phases.iter().flatten().all(|b| b.is_finite()));
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ScenarioProgram::parse("nonsense 3").is_err());
        assert!(ScenarioProgram::parse("init 1 2\n").is_err(), "missing phases");
        assert!(ScenarioProgram::parse("phases 3\n").is_err(), "missing init");
        assert!(
            ScenarioProgram::parse("phases 3\ninit 1 2\nevent 0 warp 9").is_err(),
            "unknown event kind"
        );
    }

    #[test]
    fn shrink_moves_strictly_reduce_size() {
        let p = sample_program();
        let moves = p.shrink_moves();
        assert!(!moves.is_empty());
        for m in &moves {
            assert!(
                m.size() < p.size(),
                "move did not shrink: {} vs {}",
                m.size(),
                p.size()
            );
        }
    }

    #[test]
    fn corpus_is_complete_and_compiles() {
        let c = corpus(8, true, 42);
        assert!(c.len() >= 10, "corpus shrank to {}", c.len());
        for want in [
            "drift",
            "degrade",
            "churn",
            "flash-crowd",
            "heavy-tailed",
            "heavy-tailed-lognormal",
            "correlated",
            "partition-heal",
            "stragglers",
            "zonal-outage",
            "diurnal",
        ] {
            let entry = c
                .iter()
                .find(|s| s.name == want)
                .unwrap_or_else(|| panic!("corpus is missing scenario {want}"));
            assert!(!entry.hypothesis.is_empty());
            let compiled = entry.program.compile();
            assert!(compiled.num_phases() >= 2);
            assert!(
                !compiled.reports.is_empty(),
                "{want} has no report checkpoints"
            );
            assert!(compiled.trace.phases.iter().flatten().all(|&b| b > 0.0));
        }
    }
}
