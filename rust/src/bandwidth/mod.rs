//! The bandwidth layer of the paper (§IV, §VI): the edge-capacity allocation
//! algorithm (Algorithm 1), the four bandwidth scenario models (homogeneous,
//! node-level heterogeneity, intra-server tree of Fig. 3, inter-server
//! BCube of Fig. 5) with their `M`/`e` constraint builders (Eqs. 11–19), and
//! the per-iteration / per-epoch time model (Eqs. 34–35).

pub mod allocation;
pub mod corpus;
pub mod dynamic;
pub mod fuzz;
pub mod scenario_dsl;
pub mod scenarios;
pub mod timing;

pub use scenario_dsl::{CompiledScenario, ScenarioBuilder, ScenarioEvent, ScheduledEvent};

/// One linear edge-capacity constraint row of `M z {=, ≤} e` over the logical
/// edge space: the listed edge indices consume this physical resource.
#[derive(Debug, Clone)]
pub struct ConstraintRow {
    /// Human-readable resource name ("node 3", "PIX1", "L0 port of server 7").
    pub name: String,
    /// Canonical edge-space indices with coefficient 1 in this row of `M`.
    pub edges: Vec<usize>,
    /// Capacity `e_i` (max / exact number of logical edges).
    pub cap: usize,
    /// True for equality rows (`M z = e`, the paper's node-level allocation),
    /// false for capacity upper bounds (tree links / switch ports).
    pub equality: bool,
}

/// The full constraint system handed to the heterogeneous optimizer: rows of
/// `M`, plus an eligibility mask over the edge space (edges that no physical
/// path supports — e.g. BCube pairs differing in more than one digit — are
/// forced to zero).
#[derive(Debug, Clone)]
pub struct ConstraintSet {
    /// Number of nodes.
    pub n: usize,
    /// Total edge budget `r` (cardinality constraint).
    pub r: usize,
    /// Constraint rows (`q` of them).
    pub rows: Vec<ConstraintRow>,
    /// `eligible[l]` — may logical edge `l` be selected at all?
    pub eligible: Vec<bool>,
}

impl ConstraintSet {
    /// Unconstrained (homogeneous) system: cardinality only.
    pub fn cardinality_only(n: usize, r: usize) -> ConstraintSet {
        ConstraintSet {
            n,
            r,
            rows: Vec::new(),
            eligible: vec![true; crate::graph::incidence::num_possible_edges(n)],
        }
    }

    /// Check a concrete edge selection against every row and the mask.
    /// Returns the first violation description, if any.
    pub fn check(&self, selected: &[usize]) -> Result<(), String> {
        use std::collections::HashSet;
        let sel: HashSet<usize> = selected.iter().copied().collect();
        if sel.len() > self.r {
            return Err(format!("{} edges exceed budget r={}", sel.len(), self.r));
        }
        for &l in &sel {
            if !self.eligible[l] {
                return Err(format!("edge {l} is not eligible"));
            }
        }
        for row in &self.rows {
            let used = row.edges.iter().filter(|l| sel.contains(l)).count();
            if row.equality && used != row.cap {
                return Err(format!(
                    "resource {}: {} edges != required {}",
                    row.name, used, row.cap
                ));
            }
            if !row.equality && used > row.cap {
                return Err(format!(
                    "resource {}: {} edges > capacity {}",
                    row.name, used, row.cap
                ));
            }
        }
        Ok(())
    }

    /// Number of eligible logical edges.
    pub fn num_eligible(&self) -> usize {
        self.eligible.iter().filter(|&&e| e).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cardinality_only_accepts_within_budget() {
        let cs = ConstraintSet::cardinality_only(4, 3);
        assert!(cs.check(&[0, 1, 2]).is_ok());
        assert!(cs.check(&[0, 1, 2, 3]).is_err());
        assert_eq!(cs.num_eligible(), 6);
    }

    #[test]
    fn rows_enforced() {
        let mut cs = ConstraintSet::cardinality_only(4, 6);
        cs.rows.push(ConstraintRow {
            name: "res".into(),
            edges: vec![0, 1, 2],
            cap: 1,
            equality: false,
        });
        assert!(cs.check(&[0, 3]).is_ok());
        assert!(cs.check(&[0, 1]).is_err());
        cs.rows[0].equality = true;
        assert!(cs.check(&[3, 4]).is_err()); // equality needs exactly 1 of {0,1,2}
        assert!(cs.check(&[2, 3]).is_ok());
    }

    #[test]
    fn eligibility_enforced() {
        let mut cs = ConstraintSet::cardinality_only(4, 6);
        cs.eligible[5] = false;
        assert!(cs.check(&[5]).is_err());
        assert_eq!(cs.num_eligible(), 5);
    }
}
