//! The bandwidth layer of the paper (§IV, §VI): the edge-capacity allocation
//! algorithm (Algorithm 1), the four bandwidth scenario models (homogeneous,
//! node-level heterogeneity, intra-server tree of Fig. 3, inter-server
//! BCube of Fig. 5) with their `M`/`e` constraint builders (Eqs. 11–19), and
//! the per-iteration / per-epoch time model (Eqs. 34–35).

pub mod allocation;
pub mod corpus;
pub mod dynamic;
pub mod fuzz;
pub mod scenario_dsl;
pub mod scenarios;
pub mod timing;

pub use scenario_dsl::{CompiledScenario, ScenarioBuilder, ScenarioEvent, ScheduledEvent};

/// One linear edge-capacity constraint row of `M z {=, ≤} e` over the logical
/// edge space: the listed edge indices consume this physical resource.
#[derive(Debug, Clone)]
pub struct ConstraintRow {
    /// Human-readable resource name ("node 3", "PIX1", "L0 port of server 7").
    pub name: String,
    /// Canonical edge-space indices with coefficient 1 in this row of `M`.
    pub edges: Vec<usize>,
    /// Capacity `e_i` (max / exact number of logical edges).
    pub cap: usize,
    /// True for equality rows (`M z = e`, the paper's node-level allocation),
    /// false for capacity upper bounds (tree links / switch ports).
    pub equality: bool,
}

/// The full constraint system handed to the heterogeneous optimizer: rows of
/// `M`, plus an eligibility mask over the edge space (edges that no physical
/// path supports — e.g. BCube pairs differing in more than one digit — are
/// forced to zero).
#[derive(Debug, Clone)]
pub struct ConstraintSet {
    /// Number of nodes.
    pub n: usize,
    /// Total edge budget `r` (cardinality constraint).
    pub r: usize,
    /// Constraint rows (`q` of them).
    pub rows: Vec<ConstraintRow>,
    /// `eligible[l]` — may logical edge `l` be selected at all?
    pub eligible: Vec<bool>,
}

impl ConstraintSet {
    /// Unconstrained (homogeneous) system: cardinality only.
    pub fn cardinality_only(n: usize, r: usize) -> ConstraintSet {
        ConstraintSet {
            n,
            r,
            rows: Vec::new(),
            eligible: vec![true; crate::graph::incidence::num_possible_edges(n)],
        }
    }

    /// Check a concrete edge selection against every row and the mask.
    /// Returns the first violation description, if any.
    pub fn check(&self, selected: &[usize]) -> Result<(), String> {
        use std::collections::HashSet;
        let sel: HashSet<usize> = selected.iter().copied().collect();
        if sel.len() > self.r {
            return Err(format!("{} edges exceed budget r={}", sel.len(), self.r));
        }
        for &l in &sel {
            if !self.eligible[l] {
                return Err(format!("edge {l} is not eligible"));
            }
        }
        for row in &self.rows {
            let used = row.edges.iter().filter(|l| sel.contains(l)).count();
            if row.equality && used != row.cap {
                return Err(format!(
                    "resource {}: {} edges != required {}",
                    row.name, used, row.cap
                ));
            }
            if !row.equality && used > row.cap {
                return Err(format!(
                    "resource {}: {} edges > capacity {}",
                    row.name, used, row.cap
                ));
            }
        }
        Ok(())
    }

    /// Number of eligible logical edges.
    pub fn num_eligible(&self) -> usize {
        self.eligible.iter().filter(|&&e| e).count()
    }

    /// Re-index this constraint system from the canonical edge space onto a
    /// candidate support: edge index `l` in every row/mask becomes the
    /// *position* of its pair in `cand`, and edges outside the support are
    /// dropped (they can never be selected on the sparse path). Rows left
    /// with no in-support edges are removed — except equality rows with a
    /// nonzero requirement, which become unsatisfiable and are kept so
    /// [`ConstraintSet::check`] reports the conflict instead of silently
    /// passing.
    pub fn restricted_to(&self, cand: &crate::topo::candidates::CandidateSet) -> ConstraintSet {
        use crate::graph::incidence::edge_pair;
        let mut rows = Vec::with_capacity(self.rows.len());
        for row in &self.rows {
            let edges: Vec<usize> = row
                .edges
                .iter()
                .filter_map(|&l| {
                    let (i, j) = edge_pair(self.n, l);
                    cand.position(i, j)
                })
                .collect();
            if edges.is_empty() && !(row.equality && row.cap > 0) {
                continue;
            }
            rows.push(ConstraintRow {
                name: row.name.clone(),
                edges,
                cap: row.cap,
                equality: row.equality,
            });
        }
        let eligible: Vec<bool> = (0..cand.len())
            .map(|e| {
                let (i, j) = cand.pair(e);
                self.eligible[crate::graph::incidence::edge_index(self.n, i, j)]
            })
            .collect();
        ConstraintSet {
            n: self.n,
            r: self.r,
            rows,
            eligible,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cardinality_only_accepts_within_budget() {
        let cs = ConstraintSet::cardinality_only(4, 3);
        assert!(cs.check(&[0, 1, 2]).is_ok());
        assert!(cs.check(&[0, 1, 2, 3]).is_err());
        assert_eq!(cs.num_eligible(), 6);
    }

    #[test]
    fn rows_enforced() {
        let mut cs = ConstraintSet::cardinality_only(4, 6);
        cs.rows.push(ConstraintRow {
            name: "res".into(),
            edges: vec![0, 1, 2],
            cap: 1,
            equality: false,
        });
        assert!(cs.check(&[0, 3]).is_ok());
        assert!(cs.check(&[0, 1]).is_err());
        cs.rows[0].equality = true;
        assert!(cs.check(&[3, 4]).is_err()); // equality needs exactly 1 of {0,1,2}
        assert!(cs.check(&[2, 3]).is_ok());
    }

    #[test]
    fn restricted_to_maps_rows_onto_support_positions() {
        use crate::graph::incidence::edge_index;
        use crate::topo::candidates::CandidateSet;
        let mut cs = ConstraintSet::cardinality_only(5, 4);
        cs.rows.push(ConstraintRow {
            name: "node 0".into(),
            edges: vec![edge_index(5, 0, 1), edge_index(5, 0, 4), edge_index(5, 0, 2)],
            cap: 1,
            equality: false,
        });
        cs.rows.push(ConstraintRow {
            name: "off-support".into(),
            edges: vec![edge_index(5, 1, 3)],
            cap: 1,
            equality: false,
        });
        cs.eligible[edge_index(5, 1, 2)] = false;
        let ring = vec![(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)];
        let cand = CandidateSet::from_edges(5, ring, "ring").unwrap();
        let r = cs.restricted_to(&cand);
        assert_eq!(r.eligible.len(), cand.len());
        assert!(!r.eligible[cand.position(1, 2).unwrap()]);
        // The inequality row with no in-support edges is dropped; the node
        // row keeps only its in-support edges, re-indexed to positions.
        assert_eq!(r.rows.len(), 1);
        let want = vec![cand.position(0, 1).unwrap(), cand.position(0, 4).unwrap()];
        assert_eq!(r.rows[0].edges, want);
        assert_eq!(r.r, cs.r);
    }

    #[test]
    fn eligibility_enforced() {
        let mut cs = ConstraintSet::cardinality_only(4, 6);
        cs.eligible[5] = false;
        assert!(cs.check(&[5]).is_err());
        assert_eq!(cs.num_eligible(), 5);
    }
}
