//! Dynamic bandwidth (the paper's stated future work, §VII): time-varying
//! per-node bandwidths with periodic topology re-optimization.
//!
//! The paper closes with "future work will focus on addressing dynamic
//! bandwidth scenarios with a time-varying network topology optimization
//! solution". This module provides that extension:
//!
//! - [`BandwidthTrace`] — a piecewise-constant per-node bandwidth process;
//!   rich scripted traces come from the
//!   [`ScenarioBuilder`](crate::bandwidth::scenario_dsl::ScenarioBuilder) DSL,
//!   with [`BandwidthTrace::random_walk`] / [`BandwidthTrace::degradation`]
//!   kept as presets over it,
//! - [`DynamicTopologyController`] — monitors the realized `b_min` of the
//!   current topology, and re-optimizes (warm-started from the incumbent
//!   support) when the achievable unit bandwidth improves by more than a
//!   hysteresis factor,
//! - [`simulate_dynamic_consensus`] / [`simulate_scripted_consensus`] —
//!   consensus progress under a drifting or scripted trace with and without
//!   adaptation, quantifying the benefit (plus [`PhaseReport`] checkpoints
//!   for scripted `report_stats` events).

use crate::bandwidth::scenario_dsl::{CompiledScenario, ScenarioBuilder};
use crate::bandwidth::scenarios::BandwidthScenario;
use crate::bandwidth::timing::TimeModel;
use crate::graph::Topology;
use crate::optimizer::{BaTopoOptimizer, OptimizeReport, OptimizeSpec};
use crate::util::rng::Xoshiro256pp;

/// Piecewise-constant per-node bandwidth process. Arbitrary scripted traces
/// are built with [`ScenarioBuilder`]; the constructors here are thin
/// presets over the same DSL.
#[derive(Debug, Clone)]
pub struct BandwidthTrace {
    /// Bandwidths per phase: `phases[k][i]` is node i's bandwidth in phase k.
    pub phases: Vec<Vec<f64>>,
    /// Phase duration in seconds (simulated).
    pub phase_seconds: f64,
}

impl BandwidthTrace {
    /// Multiplicative random-walk drift: each phase scales every node's
    /// bandwidth by `exp(σ·ξ)`, clamped to `[lo, hi]`.
    /// Preset for `ScenarioBuilder::new(initial).drift(sigma)`.
    pub fn random_walk(
        initial: Vec<f64>,
        phases: usize,
        sigma: f64,
        lo: f64,
        hi: f64,
        phase_seconds: f64,
        seed: u64,
    ) -> BandwidthTrace {
        ScenarioBuilder::new(initial)
            .phases(phases.max(1))
            .phase_seconds(phase_seconds)
            .clamp(lo, hi)
            .drift(sigma)
            .compile(seed)
            .trace
    }

    /// Scripted two-phase degradation: half the nodes drop to `slow_bw`
    /// (which must be positive) at phase `switch` (models e.g. co-tenant
    /// interference).
    /// Preset for `ScenarioBuilder::new(...).at_phase(switch).set_bandwidth(...)`.
    pub fn degradation(
        n: usize,
        fast_bw: f64,
        slow_bw: f64,
        phases: usize,
        switch: usize,
        phase_seconds: f64,
    ) -> BandwidthTrace {
        // Wide-open clamp: scripted values pass through exactly as given.
        let mut b = ScenarioBuilder::new(vec![fast_bw; n])
            .phases(phases.max(1))
            .phase_seconds(phase_seconds)
            .clamp(0.0, f64::INFINITY);
        if switch < phases {
            b = b.at_phase(switch);
            for i in n / 2..n {
                b = b.set_bandwidth(i, slow_bw);
            }
        }
        b.build().trace
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.phases[0].len()
    }
}

/// Re-optimization policy.
#[derive(Debug, Clone)]
pub struct DynamicPolicy {
    /// Edge budget per topology.
    pub r: usize,
    /// Re-optimize when the incumbent's round time exceeds the fresh
    /// optimum's estimate by this factor (hysteresis > 1 avoids thrashing).
    pub hysteresis: f64,
    /// Optimizer budgets (quick recommended — re-optimization happens online).
    pub quick: bool,
    /// Charge for installing a new topology (seconds of simulated time) —
    /// models the coordination barrier + connection setup.
    pub switch_cost: f64,
    /// Base RNG seed for the per-phase re-optimizations.
    pub seed: u64,
    /// Candidate edge-support spec forwarded to the optimizer (`knn:K`,
    /// `geometric:K`, `union`; `None` keeps the dense formulation). The
    /// online service sets a sparse spec so re-solves stay `O(|E_cand|)`.
    pub candidates: Option<String>,
}

impl Default for DynamicPolicy {
    fn default() -> Self {
        DynamicPolicy {
            r: 32,
            hysteresis: 1.15,
            quick: true,
            switch_cost: 0.05,
            seed: 42,
            candidates: None,
        }
    }
}

/// Outcome of one [`ReoptCore::reoptimize`] decision.
#[derive(Debug, Clone)]
pub struct ReoptOutcome {
    /// A fresh topology was installed as the new incumbent.
    pub switched: bool,
    /// The fresh solve failed (the incumbent was kept).
    pub failed: bool,
    /// τ estimate of the (pre-decision) incumbent under the observed
    /// bandwidths: simulated seconds per e-fold of consensus error
    /// (∞ during an outage, when no finite round time exists).
    pub incumbent_tau: f64,
    /// τ estimate of the fresh optimum (∞ when the solve failed).
    pub fresh_tau: f64,
    /// Solver diagnostics of the fresh solve (`None` when it failed).
    pub report: Option<OptimizeReport>,
}

/// The incumbent-maintenance / re-optimization core shared by the offline
/// [`DynamicTopologyController`] and the online `batopo serve` daemon
/// ([`crate::serve`]): it owns the incumbent topology and one decision
/// procedure — solve fresh (warm-started from the incumbent's edges via
/// [`OptimizeSpec::warm_edges`], on the sparse candidate path when
/// [`DynamicPolicy::candidates`] is set), compare τ estimates under the
/// hysteresis factor, install or keep — and never aborts on solver failure:
/// the incumbent is kept and the failure counted.
pub struct ReoptCore {
    policy: DynamicPolicy,
    incumbent: Topology,
    /// Fresh topologies installed by [`ReoptCore::reoptimize`].
    pub installs: usize,
    /// Re-optimizations that failed (incumbent kept; includes a failed
    /// initial solve, which falls back to a ring).
    pub failures: usize,
    /// Diagnostics of the most recent *successful* solve (`None` until one
    /// succeeds — e.g. after a ring fallback). The serve daemon publishes
    /// these solver-health fields alongside each topology update.
    pub last_report: Option<OptimizeReport>,
}

impl ReoptCore {
    /// Initialize by optimizing for the initial bandwidths. If that
    /// optimization is infeasible, fall back to a ring over the fleet
    /// (logged and counted in [`Self::failures`]) rather than aborting.
    pub fn new(bw0: &[f64], policy: DynamicPolicy) -> ReoptCore {
        let n = bw0.len();
        let mut failures = 0;
        let mut last_report = None;
        let incumbent = match optimize_for(bw0, &policy, policy.seed, None) {
            Ok(rep) => {
                let topo = rep.topology.clone();
                last_report = Some(rep);
                topo
            }
            Err(e) => {
                eprintln!(
                    "warning: initial dynamic optimization failed ({e}); \
                     falling back to a ring over {n} nodes"
                );
                failures += 1;
                crate::topo::baselines::ring(n)
            }
        };
        ReoptCore {
            policy,
            incumbent,
            installs: 0,
            failures,
            last_report,
        }
    }

    /// Current incumbent topology.
    pub fn incumbent(&self) -> &Topology {
        &self.incumbent
    }

    /// The policy this core runs under.
    pub fn policy(&self) -> &DynamicPolicy {
        &self.policy
    }

    /// Observe new bandwidths at `step` (a phase index or service epoch —
    /// it perturbs the solve seed) and decide: re-optimize fresh, then
    /// install the fresh topology iff the incumbent's τ estimate exceeds the
    /// fresh one by more than the hysteresis factor. An incumbent with no
    /// finite round time under the new bandwidths (scripted outage) forces a
    /// switch whenever the fresh optimum has one; a failed solve keeps the
    /// incumbent.
    pub fn reoptimize(&mut self, step: u64, bw: &[f64], tm: &TimeModel) -> ReoptOutcome {
        let sc = BandwidthScenario::NodeLevel { bw: bw.to_vec() };
        // τ ≈ t_iter / −ln(r_asym): simulated seconds per e-fold of error.
        let tau = |topo: &Topology| -> f64 {
            match tm.consensus_iter_time(&sc, topo) {
                Ok(t) => t / -topo.asymptotic_convergence_factor().max(1e-9).ln(),
                Err(_) => f64::INFINITY, // outage: no finite round time
            }
        };
        let incumbent_tau = tau(&self.incumbent);
        let seed = self.policy.seed + step;
        let warm = Some(self.incumbent.graph.edges().to_vec());
        let report = match optimize_for(bw, &self.policy, seed, warm) {
            Ok(rep) => rep,
            Err(e) => {
                eprintln!(
                    "warning: dynamic re-optimization failed at step {step} ({e}); \
                     keeping the incumbent topology"
                );
                self.failures += 1;
                return ReoptOutcome {
                    switched: false,
                    failed: true,
                    incumbent_tau,
                    fresh_tau: f64::INFINITY,
                    report: None,
                };
            }
        };
        let fresh_tau = tau(&report.topology);
        let switched = incumbent_tau > self.policy.hysteresis * fresh_tau;
        if switched {
            self.incumbent = report.topology.clone();
            self.installs += 1;
        }
        self.last_report = Some(report.clone());
        ReoptOutcome {
            switched,
            failed: false,
            incumbent_tau,
            fresh_tau,
            report: Some(report),
        }
    }
}

/// Controller state over a trace: a thin phase-indexed wrapper around
/// [`ReoptCore`] used by the scripted/dynamic consensus simulations.
pub struct DynamicTopologyController {
    core: ReoptCore,
    /// Phases at which a re-optimization was installed.
    pub switches: Vec<usize>,
    /// Online re-optimizations that failed (the incumbent topology was kept
    /// — the simulation continues instead of aborting).
    pub reopt_failures: usize,
}

impl DynamicTopologyController {
    /// Initialize by optimizing for the first phase. If that optimization is
    /// infeasible, fall back to a ring over the trace's nodes (logged and
    /// counted in [`Self::reopt_failures`]) rather than aborting.
    pub fn new(trace: &BandwidthTrace, policy: DynamicPolicy) -> DynamicTopologyController {
        let core = ReoptCore::new(&trace.phases[0], policy);
        let reopt_failures = core.failures;
        DynamicTopologyController {
            core,
            switches: Vec::new(),
            reopt_failures,
        }
    }

    /// Observe phase `k`'s bandwidths; maybe re-optimize. Returns true when a
    /// new topology was installed. A failed online re-optimization keeps the
    /// incumbent (counted in [`Self::reopt_failures`], surfaced per phase in
    /// [`PhaseReport::reopt_failures`]).
    pub fn observe(&mut self, k: usize, bw: &[f64], tm: &TimeModel) -> bool {
        let outcome = self.core.reoptimize(k as u64, bw, tm);
        self.reopt_failures = self.core.failures;
        if outcome.switched {
            self.switches.push(k);
        }
        outcome.switched
    }

    /// Current topology.
    pub fn topology(&self) -> &Topology {
        self.core.incumbent()
    }
}

fn optimize_for(
    bw: &[f64],
    policy: &DynamicPolicy,
    seed: u64,
    warm_edges: Option<Vec<(usize, usize)>>,
) -> Result<OptimizeReport, crate::optimizer::OptimizeError> {
    let sc = BandwidthScenario::NodeLevel { bw: bw.to_vec() };
    let mut spec = OptimizeSpec::with_scenario(sc, policy.r);
    if policy.quick {
        spec.max_iters = 40;
        spec.anneal_steps = 300;
        spec.polish_swaps = 8;
        spec.refine_iters = 100;
        spec.restarts = 1;
    }
    spec.seed = seed;
    spec.candidates = policy.candidates.clone();
    spec.warm_edges = warm_edges;
    // Dynamic sims run inside already-parallel reproduce sweep cells; keep
    // the online re-optimizations single-threaded.
    spec.restart_threads = 1;
    BaTopoOptimizer::new(spec).run_detailed()
}

/// Error target for [`DynamicRun::time_to_target`]: the simulated time at
/// which the normalized consensus error first drops below
/// `10^TARGET_LOG10_ERROR`. Scenario verdicts report this *time-to-target*
/// alongside spectral quantities because spectral-gap metrics alone are a
/// poor proxy for wall-clock topology quality under dynamics (Vogels et al.,
/// arXiv:2301.02151).
pub const TARGET_LOG10_ERROR: f64 = -3.0;

/// Outcome of a dynamic consensus simulation.
#[derive(Debug, Clone)]
pub struct DynamicRun {
    /// log10 of the final normalized consensus error.
    pub final_log_error: f64,
    /// Gossip rounds executed.
    pub rounds: usize,
    /// Topology switches installed (adaptive runs).
    pub switches: usize,
    /// Simulated seconds until the normalized error first reached
    /// `10^`[`TARGET_LOG10_ERROR`]; `None` if the run never got there.
    pub time_to_target: Option<f64>,
}

/// One `report_stats` checkpoint emitted at the end of its phase.
#[derive(Debug, Clone)]
pub struct PhaseReport {
    /// Phase index the checkpoint was scheduled at.
    pub phase: usize,
    /// Label from [`ScenarioBuilder::report_stats`].
    pub label: String,
    /// Simulated seconds elapsed at the end of the phase.
    pub sim_time: f64,
    /// log10 of the normalized consensus error so far.
    pub log_error: f64,
    /// Gossip rounds executed so far.
    pub rounds: usize,
    /// Topology switches installed so far.
    pub switches: usize,
    /// Online re-optimizations that failed so far (incumbent kept).
    pub reopt_failures: usize,
    /// Minimum available edge bandwidth of the current topology under the
    /// phase's bandwidths (GB/s).
    pub b_min: f64,
}

/// Outcome of a scripted run: the aggregate [`DynamicRun`] plus every
/// scheduled [`PhaseReport`].
#[derive(Debug, Clone)]
pub struct ScriptedRun {
    /// Aggregate outcome (same fields as the unscripted simulation).
    pub outcome: DynamicRun,
    /// Checkpoints, in phase order.
    pub reports: Vec<PhaseReport>,
}

/// Simulate consensus over a drifting bandwidth trace. With `adapt = false`
/// the initial topology is kept throughout (the static strawman); with
/// `adapt = true` the controller re-optimizes per phase under the policy.
pub fn simulate_dynamic_consensus(
    trace: &BandwidthTrace,
    policy: DynamicPolicy,
    adapt: bool,
    seed: u64,
) -> DynamicRun {
    simulate_core(trace, &[], policy, adapt, seed).outcome
}

/// Simulate consensus over a [`CompiledScenario`]: like
/// [`simulate_dynamic_consensus`] over the compiled trace, but additionally
/// materializes the scenario's `report_stats` checkpoints as
/// [`PhaseReport`] rows.
pub fn simulate_scripted_consensus(
    scenario: &CompiledScenario,
    policy: DynamicPolicy,
    adapt: bool,
    seed: u64,
) -> ScriptedRun {
    simulate_core(&scenario.trace, &scenario.reports, policy, adapt, seed)
}

fn simulate_core(
    trace: &BandwidthTrace,
    report_schedule: &[(usize, String)],
    policy: DynamicPolicy,
    adapt: bool,
    seed: u64,
) -> ScriptedRun {
    let n = trace.num_nodes();
    let tm = TimeModel::default();
    let dim = 32usize;
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut x: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..dim).map(|_| rng.next_gaussian()).collect())
        .collect();
    let e0 = error_of(&x).max(f64::MIN_POSITIVE);

    let mut controller = DynamicTopologyController::new(trace, policy.clone());
    let mut rounds = 0usize;
    let mut reports = Vec::with_capacity(report_schedule.len());
    let target_err = e0 * 10f64.powf(TARGET_LOG10_ERROR);
    let mut time_to_target: Option<f64> = None;
    for (k, bw) in trace.phases.iter().enumerate() {
        let sc = BandwidthScenario::NodeLevel { bw: bw.clone() };
        let mut budget = trace.phase_seconds;
        if adapt && k > 0 && controller.observe(k, bw, &tm) {
            budget -= policy.switch_cost; // pay for the switch
        }
        let topo = controller.topology().clone();
        // A scripted outage (an edge at zero bandwidth) has no finite round
        // time: the phase elapses with no gossip instead of panicking.
        let t_iter = tm
            .consensus_iter_time(&sc, &topo)
            .unwrap_or(f64::INFINITY);
        let w = &topo.weights;
        while budget >= t_iter {
            budget -= t_iter;
            rounds += 1;
            // x ← W x (dense, n ≤ 32 here).
            let mut nx = vec![vec![0.0f64; dim]; n];
            for i in 0..n {
                for j in 0..n {
                    let wij = w[(i, j)];
                    if wij == 0.0 {
                        continue;
                    }
                    for d in 0..dim {
                        nx[i][d] += wij * x[j][d];
                    }
                }
            }
            x = nx;
            if time_to_target.is_none() && error_of(&x) <= target_err {
                // Elapsed = completed phases + the spent part of this one
                // (which already includes any switch cost paid up front).
                time_to_target =
                    Some(k as f64 * trace.phase_seconds + (trace.phase_seconds - budget));
            }
        }
        for (_, label) in report_schedule.iter().filter(|(phase, _)| *phase == k) {
            reports.push(PhaseReport {
                phase: k,
                label: label.clone(),
                sim_time: (k + 1) as f64 * trace.phase_seconds,
                log_error: (error_of(&x) / e0).max(1e-300).log10(),
                rounds,
                switches: controller.switches.len(),
                reopt_failures: controller.reopt_failures,
                b_min: sc.min_edge_bandwidth(&topo),
            });
        }
    }
    ScriptedRun {
        outcome: DynamicRun {
            final_log_error: (error_of(&x) / e0).max(1e-300).log10(),
            rounds,
            switches: controller.switches.len(),
            time_to_target,
        },
        reports,
    }
}

fn error_of(x: &[Vec<f64>]) -> f64 {
    let n = x.len();
    let dim = x[0].len();
    let mut err = 0.0;
    for d in 0..dim {
        let mean: f64 = x.iter().map(|r| r[d]).sum::<f64>() / n as f64;
        for r in x {
            let v = r[d] - mean;
            err += v * v;
        }
    }
    err.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_well_formed() {
        let t = BandwidthTrace::random_walk(vec![9.76; 8], 5, 0.2, 1.0, 20.0, 1.0, 3);
        assert_eq!(t.phases.len(), 5);
        assert!(t
            .phases
            .iter()
            .flatten()
            .all(|&b| (1.0..=20.0).contains(&b)));
        let d = BandwidthTrace::degradation(8, 9.76, 2.0, 4, 2, 1.0);
        assert_eq!(d.phases[0], vec![9.76; 8]);
        assert_eq!(d.phases[2][7], 2.0);
        assert_eq!(d.phases[2][0], 9.76);
    }

    #[test]
    fn adaptation_helps_under_degradation() {
        // Half the nodes collapse to ~1/12 bandwidth mid-run: the adaptive
        // controller must reach at least as deep a consensus error as the
        // static topology (it re-balances edges onto the still-fast links).
        // At r=8 the adaptation gain is ~1.1× in the τ metric — use a tight
        // hysteresis so the controller takes it. (A well-balanced static
        // BA-Topo is remarkably degradation-tolerant; that robustness is
        // itself a finding worth keeping in the test comments.)
        let trace = BandwidthTrace::degradation(8, 9.76, 0.8, 4, 1, 1.5);
        let policy = DynamicPolicy {
            r: 8,
            hysteresis: 1.02,
            ..Default::default()
        };
        let static_run = simulate_dynamic_consensus(&trace, policy.clone(), false, 7);
        let adaptive = simulate_dynamic_consensus(&trace, policy, true, 7);
        assert!(adaptive.switches >= 1, "controller never adapted");
        assert!(
            adaptive.final_log_error <= static_run.final_log_error + 0.5,
            "adaptive {} vs static {}",
            adaptive.final_log_error,
            static_run.final_log_error
        );
    }

    #[test]
    fn zero_bandwidth_phase_pauses_gossip_instead_of_panicking() {
        // Regression: a trace that drives a node to exactly zero bandwidth
        // (an outage) used to panic inside TimeModel::iter_comm_time. The
        // phase must now simply elapse with no gossip rounds.
        let n = 6;
        let mut outage = vec![9.76; n];
        outage[0] = 0.0;
        let trace = BandwidthTrace {
            phases: vec![vec![9.76; n], outage, vec![9.76; n]],
            phase_seconds: 0.5,
        };
        let policy = DynamicPolicy {
            r: 8,
            quick: true,
            ..Default::default()
        };
        let healthy = BandwidthTrace {
            phases: vec![vec![9.76; n]; 3],
            phase_seconds: 0.5,
        };
        let run = simulate_dynamic_consensus(&trace, policy.clone(), false, 3);
        let base = simulate_dynamic_consensus(&healthy, policy, false, 3);
        assert!(run.rounds > 0, "healthy phases must still gossip");
        assert!(
            run.rounds < base.rounds,
            "outage phase executed gossip rounds: {} vs {}",
            run.rounds,
            base.rounds
        );
        assert!(run.final_log_error <= 0.0);
    }

    #[test]
    fn time_to_target_is_recorded_and_consistent() {
        let trace = BandwidthTrace {
            phases: vec![vec![9.76; 8]; 3],
            phase_seconds: 1.5,
        };
        let policy = DynamicPolicy {
            r: 10,
            ..Default::default()
        };
        let run = simulate_dynamic_consensus(&trace, policy, false, 7);
        // A healthy homogeneous trace runs ~100 rounds/phase, far more than
        // the ~7 decades/100-rounds needed for the 10^-3 target.
        assert!(run.final_log_error <= TARGET_LOG10_ERROR);
        let t = run.time_to_target.expect("target must be reached");
        assert!(t > 0.0 && t <= 4.5, "time-to-target {t} outside the horizon");

        // Phases too short for even one gossip round: no target, zero rounds.
        let dead = BandwidthTrace {
            phases: vec![vec![9.76; 8]; 2],
            phase_seconds: 1e-6,
        };
        let run = simulate_dynamic_consensus(
            &dead,
            DynamicPolicy {
                r: 10,
                ..Default::default()
            },
            false,
            7,
        );
        assert_eq!(run.rounds, 0);
        assert!(run.time_to_target.is_none());
    }

    #[test]
    fn hysteresis_prevents_thrashing_on_stable_traces() {
        let trace = BandwidthTrace::degradation(8, 9.76, 9.76, 4, 2, 1.0); // no change
        let policy = DynamicPolicy {
            r: 12,
            ..Default::default()
        };
        let run = simulate_dynamic_consensus(&trace, policy, true, 5);
        assert_eq!(run.switches, 0, "switched on a flat trace");
    }
}
