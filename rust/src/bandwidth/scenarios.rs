//! The four bandwidth scenarios of the paper's evaluation (§IV-B, §VI-A):
//!
//! 1. **Homogeneous** — every node has the same bandwidth; an edge `{i,j}`
//!    sees `min(b/dᵢ, b/dⱼ)` (§VI-A1).
//! 2. **Node-level heterogeneity** — per-node bandwidths; Algorithm 1
//!    allocates per-node edge counts and `M = abs(A)` (Eq. 16).
//! 3. **Intra-server link heterogeneity** — the standard dual-socket server
//!    of Fig. 3 modeled as a hierarchy (PIX / NODE / SYS); each logical edge
//!    maps to the lowest common component of its endpoints and shares that
//!    link's bandwidth (Eq. 17).
//! 4. **Inter-server switch-port heterogeneity** — a BCube(p,k) fabric
//!    (Fig. 5); single-digit pairs use one switch, multi-digit pairs route
//!    through intermediate servers (classic BCube digit-correcting paths),
//!    loading one port per hop endpoint (Eqs. 18–19).

use super::allocation::{allocate_edge_capacity, AllocationError};
use super::{ConstraintRow, ConstraintSet};
use crate::graph::incidence::{edge_index, num_possible_edges, EdgeSpace};
use crate::graph::Topology;

/// A physical component (link) in the intra-server hierarchy.
#[derive(Debug, Clone)]
pub struct TreeComponent {
    /// Name for diagnostics ("PIX1", "NODE2", "SYS").
    pub name: String,
    /// Leaf devices (GPUs) under this component.
    pub leaves: Vec<usize>,
    /// Link bandwidth in GB/s.
    pub bandwidth: f64,
    /// Max concurrent logical edges mapped to this link.
    pub capacity: usize,
}

/// Intra-server hierarchy specification (Fig. 3).
#[derive(Debug, Clone)]
pub struct ServerTreeSpec {
    /// Number of leaf devices.
    pub n: usize,
    /// Components sorted by ascending leaf-set size (PIX before NODE before
    /// SYS) so the first containing component is the LCA.
    pub components: Vec<TreeComponent>,
}

impl ServerTreeSpec {
    /// The paper's standard 8-GPU server (Fig. 3):
    /// `e = (1, 1, 1, 1, 4, 4, 16)`, `b_PIX : b_NODE : b_SYS = 1 : 1 : 2`
    /// with the unit bandwidth `unit_bw` (4.88 GB/s in §VI-A3).
    pub fn standard_server(unit_bw: f64) -> ServerTreeSpec {
        let comp = |name: &str, leaves: Vec<usize>, bw: f64, cap: usize| TreeComponent {
            name: name.into(),
            leaves,
            bandwidth: bw,
            capacity: cap,
        };
        ServerTreeSpec {
            n: 8,
            components: vec![
                comp("PIX1", vec![0, 1], unit_bw, 1),
                comp("PIX2", vec![2, 3], unit_bw, 1),
                comp("PIX3", vec![4, 5], unit_bw, 1),
                comp("PIX4", vec![6, 7], unit_bw, 1),
                comp("NODE1", vec![0, 1, 2, 3], unit_bw, 4),
                comp("NODE2", vec![4, 5, 6, 7], unit_bw, 4),
                comp("SYS", (0..8).collect(), 2.0 * unit_bw, 16),
            ],
        }
    }

    /// Index of the lowest common component of devices `i` and `j`.
    pub fn lca(&self, i: usize, j: usize) -> usize {
        self.components
            .iter()
            .position(|c| c.leaves.contains(&i) && c.leaves.contains(&j))
            .expect("tree must have a root containing all leaves")
    }
}

/// BCube(p, k) switch fabric specification (Fig. 5): `n = p^k` servers,
/// `k` switch layers, per-layer port bandwidths, port capacity `p − 1`.
#[derive(Debug, Clone)]
pub struct BcubeSpec {
    /// Ports per switch.
    pub p: usize,
    /// Number of layers.
    pub k: usize,
    /// Port bandwidth per layer (length `k`).
    pub layer_bw: Vec<f64>,
}

impl BcubeSpec {
    /// BCube(4, 2) with the paper's 1:2 port-bandwidth ratio
    /// (layer0 = unit, layer1 = 2·unit; unit = 4.88 GB/s in §VI-A4).
    pub fn paper_4_2(unit_bw: f64, ratio: (f64, f64)) -> BcubeSpec {
        BcubeSpec {
            p: 4,
            k: 2,
            layer_bw: vec![unit_bw * ratio.0, unit_bw * ratio.1],
        }
    }

    /// Number of servers `p^k`.
    pub fn n(&self) -> usize {
        self.p.pow(self.k as u32)
    }

    /// Digit `l` of server id `i` in base p.
    pub fn digit(&self, i: usize, l: usize) -> usize {
        (i / self.p.pow(l as u32)) % self.p
    }

    /// Layers at which `u` and `v` differ.
    pub fn diff_digits(&self, u: usize, v: usize) -> Vec<usize> {
        (0..self.k).filter(|&l| self.digit(u, l) != self.digit(v, l)).collect()
    }

    /// Routing path for a logical edge `{u, v}` as a list of hops
    /// `(layer, a, b)`: classic BCube digit-correcting routing, one digit per
    /// hop (lowest differing digit first). Single-digit pairs take one hop.
    pub fn route(&self, u: usize, v: usize) -> Vec<(usize, usize, usize)> {
        let mut hops = Vec::new();
        let mut cur = u;
        for l in self.diff_digits(u, v) {
            let base = self.p.pow(l as u32);
            let next = cur - self.digit(cur, l) * base + self.digit(v, l) * base;
            hops.push((l, cur, next));
            cur = next;
        }
        debug_assert_eq!(cur, v);
        hops
    }

    /// Per-layer port capacity `p − 1`.
    pub fn port_capacity(&self) -> usize {
        self.p - 1
    }
}

/// A bandwidth scenario: the object every experiment driver, the time model
/// and the optimizer constraint builder consume.
#[derive(Debug, Clone)]
pub enum BandwidthScenario {
    /// §VI-A1: every node at `node_bw` GB/s.
    Homogeneous { n: usize, node_bw: f64 },
    /// §VI-A2: node `i` at `bw[i]` GB/s.
    NodeLevel { bw: Vec<f64> },
    /// §VI-A3: hierarchical intra-server links.
    IntraServer(ServerTreeSpec),
    /// §VI-A4: BCube switch fabric.
    InterServer(BcubeSpec),
}

impl BandwidthScenario {
    /// The paper's homogeneous setting: n nodes at 9.76 GB/s.
    pub fn paper_homogeneous(n: usize) -> BandwidthScenario {
        BandwidthScenario::Homogeneous { n, node_bw: 9.76 }
    }

    /// The paper's node-level setting: 8 nodes at 9.76, 8 at 3.25 GB/s.
    pub fn paper_node_level() -> BandwidthScenario {
        let mut bw = vec![9.76; 8];
        bw.extend(vec![3.25; 8]);
        BandwidthScenario::NodeLevel { bw }
    }

    /// The paper's intra-server setting (Fig. 3, unit 4.88 GB/s).
    pub fn paper_intra_server() -> BandwidthScenario {
        BandwidthScenario::IntraServer(ServerTreeSpec::standard_server(4.88))
    }

    /// The paper's inter-server setting (BCube(4,2), ports 4.88/9.76 GB/s).
    pub fn paper_inter_server() -> BandwidthScenario {
        BandwidthScenario::InterServer(BcubeSpec::paper_4_2(4.88, (1.0, 2.0)))
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        match self {
            BandwidthScenario::Homogeneous { n, .. } => *n,
            BandwidthScenario::NodeLevel { bw } => bw.len(),
            BandwidthScenario::IntraServer(t) => t.n,
            BandwidthScenario::InterServer(b) => b.n(),
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            BandwidthScenario::Homogeneous { .. } => "homogeneous",
            BandwidthScenario::NodeLevel { .. } => "node-level",
            BandwidthScenario::IntraServer(_) => "intra-server",
            BandwidthScenario::InterServer(_) => "inter-server",
        }
    }

    /// Available bandwidth of every edge of `topo` (aligned with
    /// `topo.graph.edges()`), under this scenario's sharing rules.
    pub fn edge_bandwidths(&self, topo: &Topology) -> Vec<f64> {
        let edges = topo.graph.edges();
        match self {
            BandwidthScenario::Homogeneous { n, node_bw } => {
                assert_eq!(*n, topo.num_nodes());
                let deg = topo.comm_degrees();
                edges
                    .iter()
                    .map(|&(i, j)| (node_bw / deg[i] as f64).min(node_bw / deg[j] as f64))
                    .collect()
            }
            BandwidthScenario::NodeLevel { bw } => {
                assert_eq!(bw.len(), topo.num_nodes());
                let deg = topo.comm_degrees();
                edges
                    .iter()
                    .map(|&(i, j)| (bw[i] / deg[i] as f64).min(bw[j] / deg[j] as f64))
                    .collect()
            }
            BandwidthScenario::IntraServer(tree) => {
                assert_eq!(tree.n, topo.num_nodes());
                // Load per component = edges mapped (LCA) onto it.
                let mut load = vec![0usize; tree.components.len()];
                let lcas: Vec<usize> = edges.iter().map(|&(i, j)| tree.lca(i, j)).collect();
                for &c in &lcas {
                    load[c] += 1;
                }
                lcas.iter()
                    .map(|&c| tree.components[c].bandwidth / load[c] as f64)
                    .collect()
            }
            BandwidthScenario::InterServer(bc) => {
                assert_eq!(bc.n(), topo.num_nodes());
                // Load per port (layer, server) over all hops of all edges.
                let n = bc.n();
                let mut load = vec![vec![0usize; n]; bc.k];
                let routes: Vec<Vec<(usize, usize, usize)>> =
                    edges.iter().map(|&(u, v)| bc.route(u, v)).collect();
                for hops in &routes {
                    for &(l, a, b) in hops {
                        load[l][a] += 1;
                        load[l][b] += 1;
                    }
                }
                routes
                    .iter()
                    .map(|hops| {
                        hops.iter()
                            .map(|&(l, a, b)| {
                                let worst = load[l][a].max(load[l][b]) as f64;
                                bc.layer_bw[l] / worst
                            })
                            .fold(f64::INFINITY, f64::min)
                    })
                    .collect()
            }
        }
    }

    /// Minimum available edge bandwidth — `b_min` of Eq. 34/35.
    pub fn min_edge_bandwidth(&self, topo: &Topology) -> f64 {
        self.edge_bandwidths(topo)
            .into_iter()
            .fold(f64::INFINITY, f64::min)
    }

    /// Build the optimizer constraint system `M z {=,≤} e` plus eligibility
    /// mask for edge budget `r` (Eqs. 11–19).
    pub fn constraints(&self, r: usize) -> Result<ConstraintSet, AllocationError> {
        let n = self.num_nodes();
        match self {
            BandwidthScenario::Homogeneous { node_bw, .. } => {
                // The paper's constraints are bandwidth-aware in the
                // homogeneous case too (§I): Algorithm 1 with uniform node
                // bandwidths balances degrees at ⌊2r/n⌋/⌈2r/n⌉ (Fig. 1's
                // "BA-Topo (r=16, d=2)"), keeping every edge at b/⌈2r/n⌉.
                let bw = vec![*node_bw; n];
                let caps = vec![n - 1; n];
                let alloc = allocate_edge_capacity(&bw, r, &caps)?;
                let rows = (0..n)
                    .map(|i| ConstraintRow {
                        name: format!("node {i}"),
                        edges: (0..n)
                            .filter(|&j| j != i)
                            .map(|j| edge_index(n, i, j))
                            .collect(),
                        cap: alloc.edges_per_node[i],
                        equality: true,
                    })
                    .collect();
                Ok(ConstraintSet {
                    n,
                    r,
                    rows,
                    eligible: vec![true; num_possible_edges(n)],
                })
            }
            BandwidthScenario::NodeLevel { bw } => {
                let caps = vec![n - 1; n];
                let alloc = allocate_edge_capacity(bw, r, &caps)?;
                let rows = (0..n)
                    .map(|i| ConstraintRow {
                        name: format!("node {i}"),
                        edges: (0..n)
                            .filter(|&j| j != i)
                            .map(|j| edge_index(n, i, j))
                            .collect(),
                        cap: alloc.edges_per_node[i],
                        equality: true,
                    })
                    .collect();
                Ok(ConstraintSet {
                    n,
                    r,
                    rows,
                    eligible: vec![true; num_possible_edges(n)],
                })
            }
            BandwidthScenario::IntraServer(tree) => {
                // Algorithm 1 over the physical links (multiplicity 1: each
                // edge maps to exactly its LCA link): the allocated per-link
                // edge counts bound contention so every edge keeps ≥ b_unit.
                let bw: Vec<f64> = tree.components.iter().map(|c| c.bandwidth).collect();
                let hw_caps: Vec<usize> = tree.components.iter().map(|c| c.capacity).collect();
                let alloc = super::allocation::allocate_resource_capacity(&bw, r, &hw_caps, 1)?;
                let mut rows: Vec<ConstraintRow> = tree
                    .components
                    .iter()
                    .zip(&alloc.edges_per_node)
                    .map(|(c, &cap)| ConstraintRow {
                        name: c.name.clone(),
                        edges: Vec::new(),
                        cap,
                        equality: false,
                    })
                    .collect();
                for (l, (i, j)) in EdgeSpace::new(n) {
                    rows[tree.lca(i, j)].edges.push(l);
                }
                Ok(ConstraintSet {
                    n,
                    r,
                    rows,
                    eligible: vec![true; num_possible_edges(n)],
                })
            }
            BandwidthScenario::InterServer(bc) => {
                // Eligible: pairs differing in exactly one digit (single-hop).
                let mut eligible = vec![false; num_possible_edges(n)];
                let mut port_edges: Vec<Vec<Vec<usize>>> = vec![vec![Vec::new(); n]; bc.k];
                for (l, (u, v)) in EdgeSpace::new(n) {
                    let d = bc.diff_digits(u, v);
                    if d.len() == 1 {
                        eligible[l] = true;
                        let layer = d[0];
                        port_edges[layer][u].push(l);
                        port_edges[layer][v].push(l);
                    }
                }
                // Algorithm 1 over the switch ports (multiplicity 2: an edge
                // occupies one port at each endpoint, same layer).
                let mut bw = Vec::with_capacity(bc.k * n);
                for layer in 0..bc.k {
                    bw.extend(std::iter::repeat(bc.layer_bw[layer]).take(n));
                }
                let hw_caps = vec![bc.port_capacity(); bc.k * n];
                let alloc = super::allocation::allocate_resource_capacity(&bw, r, &hw_caps, 2)?;
                let mut rows = Vec::with_capacity(bc.k * n);
                for layer in 0..bc.k {
                    for srv in 0..n {
                        rows.push(ConstraintRow {
                            name: format!("L{layer} port of server {srv}"),
                            edges: port_edges[layer][srv].clone(),
                            cap: alloc.edges_per_node[layer * n + srv],
                            equality: false,
                        });
                    }
                }
                Ok(ConstraintSet {
                    n,
                    r,
                    rows,
                    eligible,
                })
            }
        }
    }

    /// [`BandwidthScenario::constraints`] re-indexed onto a candidate
    /// support: every edge index in the rows and the eligibility mask is a
    /// candidate *position*, not a canonical edge-space index.
    ///
    /// The node-degree scenarios (homogeneous, node-level) build their rows
    /// directly over the support — `O(|E_cand|)` instead of the `O(n²)`
    /// node-row materialization of the full builder, which is what lets the
    /// sparse optimizer assemble constraints at n=16384. The fixed-size
    /// hardware scenarios (intra-server, inter-server) are tiny, so they go
    /// through the full builder and [`ConstraintSet::restricted_to`].
    pub fn constraints_on(
        &self,
        r: usize,
        cand: &crate::topo::candidates::CandidateSet,
    ) -> Result<ConstraintSet, AllocationError> {
        let n = self.num_nodes();
        assert_eq!(cand.n(), n, "candidate support node count mismatch");
        let node_bw: Option<Vec<f64>> = match self {
            BandwidthScenario::Homogeneous { node_bw, .. } => Some(vec![*node_bw; n]),
            BandwidthScenario::NodeLevel { bw } => Some(bw.clone()),
            _ => None,
        };
        let Some(bw) = node_bw else {
            return Ok(self.constraints(r)?.restricted_to(cand));
        };
        let caps = vec![n - 1; n];
        let alloc = allocate_edge_capacity(&bw, r, &caps)?;
        let mut rows: Vec<ConstraintRow> = (0..n)
            .map(|i| ConstraintRow {
                name: format!("node {i}"),
                edges: Vec::new(),
                cap: alloc.edges_per_node[i],
                equality: true,
            })
            .collect();
        for (e, &(a, b)) in cand.edges().iter().enumerate() {
            rows[a].edges.push(e);
            rows[b].edges.push(e);
        }
        Ok(ConstraintSet {
            n,
            r,
            rows,
            eligible: vec![true; cand.len()],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::baselines;
    use crate::topo::candidates::CandidateSet;

    #[test]
    fn constraints_on_matches_restricted_full_build() {
        let sc = BandwidthScenario::paper_node_level();
        let cand = CandidateSet::generate("union", &sc, 3).unwrap();
        let direct = sc.constraints_on(16, &cand).unwrap();
        let restricted = sc.constraints(16).unwrap().restricted_to(&cand);
        assert_eq!(direct.eligible, restricted.eligible);
        assert_eq!(direct.rows.len(), restricted.rows.len());
        for (a, b) in direct.rows.iter().zip(&restricted.rows) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.cap, b.cap);
            assert_eq!(a.equality, b.equality);
            let (mut ea, mut eb) = (a.edges.clone(), b.edges.clone());
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "row {}", a.name);
        }
    }

    #[test]
    fn constraints_on_intra_server_restricts() {
        let sc = BandwidthScenario::paper_intra_server();
        let cand = CandidateSet::generate("geometric:2", &sc, 1).unwrap();
        let cs = sc.constraints_on(8, &cand).unwrap();
        assert_eq!(cs.eligible.len(), cand.len());
        // Every candidate edge still maps onto exactly one LCA link row.
        let total: usize = cs.rows.iter().map(|r| r.edges.len()).sum();
        assert_eq!(total, cand.len());
    }

    #[test]
    fn homogeneous_edge_bandwidths_ring() {
        let topo = baselines::ring(8);
        let sc = BandwidthScenario::paper_homogeneous(8);
        let bws = sc.edge_bandwidths(&topo);
        assert!(bws.iter().all(|&b| (b - 9.76 / 2.0).abs() < 1e-12));
    }

    #[test]
    fn exponential_uses_out_degree() {
        // §VI-A1: for the exponential topology, degrees mean out-degree (4 at
        // n=16), so every link sees 9.76/4.
        let topo = baselines::exponential(16);
        let sc = BandwidthScenario::paper_homogeneous(16);
        let b = sc.min_edge_bandwidth(&topo);
        assert!((b - 9.76 / 4.0).abs() < 1e-12, "b={b}");
    }

    #[test]
    fn node_level_min_edge_bandwidth() {
        let topo = baselines::ring(16);
        let sc = BandwidthScenario::paper_node_level();
        // Slow nodes (3.25) with degree 2 bound the ring: 3.25/2.
        let b = sc.min_edge_bandwidth(&topo);
        assert!((b - 3.25 / 2.0).abs() < 1e-12, "b={b}");
    }

    #[test]
    fn intra_server_lca_mapping() {
        let tree = ServerTreeSpec::standard_server(4.88);
        assert_eq!(tree.components[tree.lca(0, 1)].name, "PIX1");
        assert_eq!(tree.components[tree.lca(0, 2)].name, "NODE1");
        assert_eq!(tree.components[tree.lca(0, 4)].name, "SYS");
        assert_eq!(tree.components[tree.lca(6, 7)].name, "PIX4");
    }

    #[test]
    fn exponential_overloads_sys_link_as_paper_reports() {
        // §VI-A3: "the exponential topology maps 10 edges onto the SYS
        // physical link, resulting in a minimum available edge bandwidth of
        // only 0.976 GB/s".
        let topo = baselines::exponential(8);
        let sc = BandwidthScenario::paper_intra_server();
        let tree = match &sc {
            BandwidthScenario::IntraServer(t) => t,
            _ => unreachable!(),
        };
        let sys = tree.components.len() - 1;
        let sys_edges = topo
            .graph
            .edges()
            .iter()
            .filter(|&&(i, j)| tree.lca(i, j) == sys)
            .count();
        assert_eq!(sys_edges, 10);
        let b = sc.min_edge_bandwidth(&topo);
        assert!((b - 0.976).abs() < 1e-9, "b={b}");
    }

    #[test]
    fn intra_server_capacity_rows_partition_edge_space() {
        let sc = BandwidthScenario::paper_intra_server();
        let cs = sc.constraints(12).unwrap();
        let total: usize = cs.rows.iter().map(|r| r.edges.len()).sum();
        assert_eq!(total, num_possible_edges(8)); // 28: every pair has one LCA
        // Row caps are the Algorithm-1 allocation over links (bounded by the
        // paper's hardware caps e = (1,1,1,1,4,4,16)).
        let caps: Vec<usize> = cs.rows.iter().map(|r| r.cap).collect();
        assert_eq!(caps, vec![1, 1, 1, 1, 2, 2, 4]); // r=12 → b_unit 2.44
        assert_eq!(caps.iter().sum::<usize>(), 12);
        // r=8 is the paper's full-unit-bandwidth case.
        let cs8 = sc.constraints(8).unwrap();
        let caps8: Vec<usize> = cs8.rows.iter().map(|r| r.cap).collect();
        assert_eq!(caps8, vec![1, 1, 1, 1, 1, 1, 2]);
    }

    #[test]
    fn bcube_digits_and_routes() {
        let bc = BcubeSpec::paper_4_2(4.88, (1.0, 2.0));
        assert_eq!(bc.n(), 16);
        assert_eq!(bc.digit(7, 0), 3);
        assert_eq!(bc.digit(7, 1), 1);
        // Single-digit pair: one hop.
        assert_eq!(bc.route(0, 3), vec![(0, 0, 3)]);
        assert_eq!(bc.route(0, 8), vec![(1, 0, 8)]);
        // Two-digit pair routes through an intermediate server.
        let hops = bc.route(1, 14); // 1=(0,1) → 14=(3,2)
        assert_eq!(hops.len(), 2);
        assert_eq!(hops[0].1, 1);
        assert_eq!(hops[1].2, 14);
    }

    #[test]
    fn bcube_eligibility_and_ports() {
        let sc = BandwidthScenario::paper_inter_server();
        let cs = sc.constraints(24).unwrap();
        // 16 servers × (3 peers per layer × 2 layers) / 2 = 48 eligible.
        assert_eq!(cs.num_eligible(), 48);
        assert_eq!(cs.rows.len(), 32); // 2 layers × 16 ports
        // Allocation at r=24 keeps b_unit = 4.88: 1 edge per slow L0 port,
        // 2 per fast L1 port (hardware cap would be p−1 = 3).
        assert!(cs.rows[..16].iter().all(|r| r.cap == 1));
        assert!(cs.rows[16..].iter().all(|r| r.cap == 2));
        // Every port carries exactly p-1 = 3 eligible edges.
        assert!(cs.rows.iter().all(|r| r.edges.len() == 3));
    }

    #[test]
    fn node_level_constraints_use_algorithm1() {
        let sc = BandwidthScenario::paper_node_level();
        let cs = sc.constraints(16).unwrap();
        assert_eq!(cs.rows.len(), 16);
        let caps: Vec<usize> = cs.rows.iter().map(|r| r.cap).collect();
        assert_eq!(caps[..8], [3, 3, 3, 3, 3, 3, 3, 3]);
        assert_eq!(caps[8..], [1, 1, 1, 1, 1, 1, 1, 1]);
        assert!(cs.rows.iter().all(|r| r.equality));
    }

    #[test]
    fn bcube_ring_bandwidth_penalized_by_multihop() {
        // A ring laid naively over BCube labels crosses digit boundaries and
        // must multi-hop — its min edge bandwidth is worse than any
        // single-hop topology at equal degree.
        let ring = baselines::ring(16);
        let sc = BandwidthScenario::paper_inter_server();
        let b_ring = sc.min_edge_bandwidth(&ring);
        assert!(b_ring < 4.88 / 2.0, "b_ring={b_ring}");
    }
}
