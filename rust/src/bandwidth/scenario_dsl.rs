//! Scripted bandwidth scenarios: a fluent [`ScenarioBuilder`] DSL that
//! compiles phase-indexed events into a [`BandwidthTrace`] plus a report
//! schedule, replacing the hardcoded random-walk-only traces that the
//! dynamic-bandwidth extension (`bandwidth::dynamic`, the paper's §VII future
//! work) started from.
//!
//! A scenario is a sequence of **phases** (piecewise-constant bandwidth
//! intervals). The builder positions a cursor with [`at_phase`] and attaches
//! events at it:
//!
//! ```
//! use batopo::bandwidth::scenario_dsl::ScenarioBuilder;
//!
//! let scenario = ScenarioBuilder::new(vec![9.76; 8])
//!     .phases(6)
//!     .phase_seconds(1.5)
//!     .at_phase(0).drift(0.10)                  // background random walk
//!     .at_phase(2).link_degrade(&[4, 5, 6, 7], 0.25)
//!     .at_phase(2).report_stats("after degradation")
//!     .at_phase(4).node_churn(2, None)          // node 2 leaves
//!     .at_phase(5).node_churn(2, Some(9.76))    // ...and rejoins
//!     .at_phase(5).report_stats("after recovery")
//!     .compile(42);
//! assert_eq!(scenario.trace.phases.len(), 6);
//! assert_eq!(scenario.reports.len(), 2);
//! ```
//!
//! The compiled trace feeds [`DynamicTopologyController`] and
//! [`simulate_scripted_consensus`]; the report schedule turns into
//! [`PhaseReport`] rows (the `report_stats` checkpoints of the EcNode-style
//! scenario-analysis workflow).
//!
//! [`at_phase`]: ScenarioBuilder::at_phase
//! [`DynamicTopologyController`]: crate::bandwidth::dynamic::DynamicTopologyController
//! [`simulate_scripted_consensus`]: crate::bandwidth::dynamic::simulate_scripted_consensus
//! [`PhaseReport`]: crate::bandwidth::dynamic::PhaseReport

use crate::bandwidth::dynamic::BandwidthTrace;
use crate::util::rng::Xoshiro256pp;

/// One scripted event. Events fire at the **start** of their phase, after the
/// background drift step (so an explicit `set_bandwidth` wins over drift
/// within its phase).
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioEvent {
    /// Set the multiplicative random-walk drift rate from this phase on:
    /// every later phase transition scales each node's bandwidth by
    /// `exp(σ·ξ)`, `ξ ~ N(0,1)`. `sigma = 0` turns drift off again.
    Drift {
        /// Per-phase log-scale drift rate σ.
        sigma: f64,
    },
    /// Pin one node's bandwidth to an exact value (GB/s).
    SetBandwidth {
        /// Node index.
        node: usize,
        /// New bandwidth in GB/s.
        bw: f64,
    },
    /// Scale a set of nodes' bandwidths by a factor (e.g. co-tenant
    /// interference at `factor < 1`, recovery at `factor > 1`).
    LinkDegrade {
        /// Affected node indices.
        nodes: Vec<usize>,
        /// Multiplicative factor applied to each node's current bandwidth.
        factor: f64,
    },
    /// Node churn: with `rejoin_bw = None` the node leaves (its bandwidth
    /// collapses to the churn floor, so the optimizer routes around it);
    /// with `Some(bw)` it rejoins at that bandwidth.
    NodeChurn {
        /// Node index.
        node: usize,
        /// `None` = leave, `Some(bw)` = rejoin at `bw` GB/s.
        rejoin_bw: Option<f64>,
    },
    /// Emit a labelled stats checkpoint at the end of this phase (consumed by
    /// [`simulate_scripted_consensus`]).
    ///
    /// [`simulate_scripted_consensus`]: crate::bandwidth::dynamic::simulate_scripted_consensus
    ReportStats {
        /// Checkpoint label for reports/CSV.
        label: String,
    },
}

/// A [`ScenarioEvent`] bound to its phase index.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledEvent {
    /// Phase at which the event fires.
    pub phase: usize,
    /// The event itself.
    pub event: ScenarioEvent,
}

/// Fluent builder for scripted bandwidth scenarios. See the
/// [module docs](self) for an end-to-end example.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    initial: Vec<f64>,
    phase_seconds: f64,
    horizon: Option<usize>,
    lo: f64,
    hi: f64,
    churn_floor: f64,
    cursor: usize,
    events: Vec<ScheduledEvent>,
}

impl ScenarioBuilder {
    /// Start a scenario from per-node initial bandwidths (GB/s). The cursor
    /// starts at phase 0; phase duration defaults to 1 simulated second.
    pub fn new(initial_bw: Vec<f64>) -> ScenarioBuilder {
        assert!(!initial_bw.is_empty(), "scenario needs at least one node");
        ScenarioBuilder {
            initial: initial_bw,
            phase_seconds: 1.0,
            horizon: None,
            lo: 1e-3,
            hi: f64::INFINITY,
            churn_floor: 0.05,
            cursor: 0,
            events: Vec::new(),
        }
    }

    /// Set the simulated duration of every phase (seconds).
    pub fn phase_seconds(mut self, seconds: f64) -> Self {
        assert!(seconds > 0.0, "phase duration must be positive");
        self.phase_seconds = seconds;
        self
    }

    /// Set the scenario horizon (total number of phases). Without an explicit
    /// horizon the trace extends to the last scheduled event; the horizon is
    /// never shorter than that.
    pub fn phases(mut self, phases: usize) -> Self {
        assert!(phases > 0, "scenario needs at least one phase");
        self.horizon = Some(phases);
        self
    }

    /// Clamp all bandwidths (drifted or scripted) to `[lo, hi]` GB/s.
    /// Defaults to `[1e-3, ∞)`; `lo = 0` is permitted for raw traces, but
    /// note the time model divides by `b_min`, so a simulated scenario needs
    /// strictly positive bandwidths.
    pub fn clamp(mut self, lo: f64, hi: f64) -> Self {
        assert!(lo >= 0.0 && hi >= lo, "need 0 <= lo <= hi");
        self.lo = lo;
        self.hi = hi;
        self
    }

    /// Bandwidth assigned to a node that leaves via [`node_churn`]
    /// (default 0.05 GB/s — effectively unreachable, but nonzero so the
    /// Algorithm-1 allocation stays well-defined).
    ///
    /// [`node_churn`]: ScenarioBuilder::node_churn
    pub fn churn_floor(mut self, bw: f64) -> Self {
        assert!(bw > 0.0, "churn floor must be positive");
        self.churn_floor = bw;
        self
    }

    /// Move the cursor: subsequent events attach to phase `k`.
    pub fn at_phase(mut self, k: usize) -> Self {
        self.cursor = k;
        self
    }

    fn push(mut self, event: ScenarioEvent) -> Self {
        self.events.push(ScheduledEvent {
            phase: self.cursor,
            event,
        });
        self
    }

    /// Enable random-walk drift with rate `sigma` from the cursor phase on
    /// (see [`ScenarioEvent::Drift`]).
    pub fn drift(self, sigma: f64) -> Self {
        assert!(sigma >= 0.0, "drift sigma must be non-negative");
        self.push(ScenarioEvent::Drift { sigma })
    }

    fn check_node(&self, node: usize) {
        assert!(
            node < self.initial.len(),
            "node {node} out of range (scenario has {} nodes)",
            self.initial.len()
        );
    }

    /// Pin `node`'s bandwidth to `bw` GB/s at the cursor phase.
    pub fn set_bandwidth(self, node: usize, bw: f64) -> Self {
        self.check_node(node);
        assert!(bw > 0.0, "bandwidth must be positive");
        self.push(ScenarioEvent::SetBandwidth { node, bw })
    }

    /// Scale `nodes`' bandwidths by `factor` at the cursor phase.
    pub fn link_degrade(self, nodes: &[usize], factor: f64) -> Self {
        for &i in nodes {
            self.check_node(i);
        }
        assert!(factor > 0.0, "degradation factor must be positive");
        self.push(ScenarioEvent::LinkDegrade {
            nodes: nodes.to_vec(),
            factor,
        })
    }

    /// Node churn at the cursor phase: `None` = node leaves (bandwidth drops
    /// to the churn floor), `Some(bw)` = node rejoins at `bw` GB/s.
    pub fn node_churn(self, node: usize, rejoin_bw: Option<f64>) -> Self {
        self.check_node(node);
        if let Some(bw) = rejoin_bw {
            assert!(bw > 0.0, "rejoin bandwidth must be positive");
        }
        self.push(ScenarioEvent::NodeChurn { node, rejoin_bw })
    }

    /// Schedule a labelled stats checkpoint at the end of the cursor phase.
    pub fn report_stats(self, label: &str) -> Self {
        self.push(ScenarioEvent::ReportStats {
            label: label.to_string(),
        })
    }

    /// Events scheduled so far (insertion order).
    pub fn events(&self) -> &[ScheduledEvent] {
        &self.events
    }

    /// Compile with a fixed drift seed. Walks phases in order carrying the
    /// current bandwidth vector: each transition applies the active drift
    /// (if any), then the phase's scripted events in schedule order.
    pub fn compile(self, seed: u64) -> CompiledScenario {
        let min_horizon = self
            .events
            .iter()
            .map(|e| e.phase + 1)
            .max()
            .unwrap_or(1);
        let horizon = self.horizon.unwrap_or(min_horizon).max(min_horizon);

        let mut events = self.events;
        events.sort_by_key(|e| e.phase); // stable: same-phase order preserved

        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut bw = self.initial;
        let mut sigma = 0.0f64;
        let mut phases = Vec::with_capacity(horizon);
        let mut reports = Vec::new();
        for k in 0..horizon {
            if k > 0 && sigma > 0.0 {
                for b in bw.iter_mut() {
                    *b = (*b * (sigma * rng.next_gaussian()).exp()).clamp(self.lo, self.hi);
                }
            }
            for ev in events.iter().filter(|e| e.phase == k) {
                match &ev.event {
                    ScenarioEvent::Drift { sigma: s } => sigma = *s,
                    ScenarioEvent::SetBandwidth { node, bw: v } => {
                        bw[*node] = v.clamp(self.lo, self.hi);
                    }
                    ScenarioEvent::LinkDegrade { nodes, factor } => {
                        for &i in nodes {
                            bw[i] = (bw[i] * factor).clamp(self.lo, self.hi);
                        }
                    }
                    ScenarioEvent::NodeChurn { node, rejoin_bw } => {
                        bw[*node] = match rejoin_bw {
                            Some(v) => v.clamp(self.lo, self.hi),
                            None => self.churn_floor,
                        };
                    }
                    ScenarioEvent::ReportStats { label } => {
                        reports.push((k, label.clone()));
                    }
                }
            }
            phases.push(bw.clone());
        }
        CompiledScenario {
            trace: BandwidthTrace {
                phases,
                phase_seconds: self.phase_seconds,
            },
            reports,
            events,
        }
    }

    /// Compile with the default drift seed 0. Scenarios without [`drift`]
    /// events are fully deterministic, so the seed is irrelevant for them.
    ///
    /// [`drift`]: ScenarioBuilder::drift
    pub fn build(self) -> CompiledScenario {
        self.compile(0)
    }
}

/// A compiled scenario: the bandwidth trace plus the event/report schedule.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    /// Piecewise-constant per-node bandwidth trace (one row per phase).
    pub trace: BandwidthTrace,
    /// `(phase, label)` checkpoints from [`ScenarioBuilder::report_stats`],
    /// in phase order.
    pub reports: Vec<(usize, String)>,
    /// The full event schedule, sorted by phase (stable).
    pub events: Vec<ScheduledEvent>,
}

impl CompiledScenario {
    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.trace.num_nodes()
    }

    /// Number of phases.
    pub fn num_phases(&self) -> usize {
        self.trace.phases.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_compile_in_phase_order() {
        // Events scheduled out of order still apply phase-by-phase.
        let s = ScenarioBuilder::new(vec![10.0; 4])
            .at_phase(3)
            .set_bandwidth(0, 1.0)
            .at_phase(1)
            .set_bandwidth(0, 5.0)
            .build();
        assert_eq!(s.num_phases(), 4);
        assert_eq!(s.trace.phases[0][0], 10.0);
        assert_eq!(s.trace.phases[1][0], 5.0);
        assert_eq!(s.trace.phases[2][0], 5.0); // persists until next event
        assert_eq!(s.trace.phases[3][0], 1.0);
        // Schedule is sorted by phase after compile.
        assert!(s.events.windows(2).all(|w| w[0].phase <= w[1].phase));
    }

    #[test]
    fn horizon_extends_to_last_event() {
        let s = ScenarioBuilder::new(vec![1.0]).at_phase(7).report_stats("x").build();
        assert_eq!(s.num_phases(), 8);
        let s2 = ScenarioBuilder::new(vec![1.0]).phases(3).build();
        assert_eq!(s2.num_phases(), 3);
    }

    #[test]
    fn degrade_churn_and_clamp() {
        let s = ScenarioBuilder::new(vec![8.0; 4])
            .clamp(0.5, 10.0)
            .phases(4)
            .at_phase(1)
            .link_degrade(&[2, 3], 0.01) // would be 0.08, clamped to 0.5
            .at_phase(2)
            .node_churn(0, None)
            .at_phase(3)
            .node_churn(0, Some(6.0))
            .build();
        assert_eq!(s.trace.phases[1][2], 0.5);
        assert_eq!(s.trace.phases[1][3], 0.5);
        assert_eq!(s.trace.phases[1][0], 8.0);
        assert_eq!(s.trace.phases[2][0], 0.05); // churn floor, below clamp by design
        assert_eq!(s.trace.phases[3][0], 6.0);
    }

    #[test]
    fn drift_is_seeded_and_clamped() {
        let a = ScenarioBuilder::new(vec![5.0; 6])
            .phases(10)
            .clamp(1.0, 20.0)
            .drift(0.4)
            .compile(9);
        let b = ScenarioBuilder::new(vec![5.0; 6])
            .phases(10)
            .clamp(1.0, 20.0)
            .drift(0.4)
            .compile(9);
        assert_eq!(a.trace.phases, b.trace.phases, "same seed, same trace");
        assert!(a.trace.phases.iter().flatten().all(|&x| (1.0..=20.0).contains(&x)));
        // Drift actually moves the values.
        assert_ne!(a.trace.phases[0], a.trace.phases[9]);
        let c = ScenarioBuilder::new(vec![5.0; 6])
            .phases(10)
            .clamp(1.0, 20.0)
            .drift(0.4)
            .compile(10);
        assert_ne!(a.trace.phases, c.trace.phases, "different seed, different trace");
    }

    #[test]
    fn drift_can_be_turned_off() {
        let s = ScenarioBuilder::new(vec![5.0; 2])
            .phases(6)
            .drift(0.5)
            .at_phase(3)
            .drift(0.0)
            .compile(4);
        // After phase 3 the values freeze.
        assert_eq!(s.trace.phases[4], s.trace.phases[3]);
        assert_eq!(s.trace.phases[5], s.trace.phases[3]);
        assert_ne!(s.trace.phases[0], s.trace.phases[3]);
    }

    #[test]
    fn reports_are_collected_in_phase_order() {
        let s = ScenarioBuilder::new(vec![1.0; 2])
            .at_phase(4)
            .report_stats("late")
            .at_phase(1)
            .report_stats("early")
            .build();
        assert_eq!(
            s.reports,
            vec![(1, "early".to_string()), (4, "late".to_string())]
        );
    }
}
