//! Scripted bandwidth scenarios: a fluent [`ScenarioBuilder`] DSL that
//! compiles phase-indexed events into a [`BandwidthTrace`] plus a report
//! schedule, replacing the hardcoded random-walk-only traces that the
//! dynamic-bandwidth extension (`bandwidth::dynamic`, the paper's §VII future
//! work) started from.
//!
//! A scenario is a sequence of **phases** (piecewise-constant bandwidth
//! intervals). The builder positions a cursor with [`at_phase`] and attaches
//! events at it:
//!
//! ```
//! use batopo::bandwidth::scenario_dsl::ScenarioBuilder;
//!
//! let scenario = ScenarioBuilder::new(vec![9.76; 8])
//!     .phases(6)
//!     .phase_seconds(1.5)
//!     .at_phase(0).drift(0.10)                  // background random walk
//!     .at_phase(2).link_degrade(&[4, 5, 6, 7], 0.25)
//!     .at_phase(2).report_stats("after degradation")
//!     .at_phase(4).node_churn(2, None)          // node 2 leaves
//!     .at_phase(5).node_churn(2, Some(9.76))    // ...and rejoins
//!     .at_phase(5).report_stats("after recovery")
//!     .compile(42);
//! assert_eq!(scenario.trace.phases.len(), 6);
//! assert_eq!(scenario.reports.len(), 2);
//! ```
//!
//! The compiled trace feeds [`DynamicTopologyController`] and
//! [`simulate_scripted_consensus`]; the report schedule turns into
//! [`PhaseReport`] rows (the `report_stats` checkpoints of the EcNode-style
//! scenario-analysis workflow).
//!
//! [`at_phase`]: ScenarioBuilder::at_phase
//! [`DynamicTopologyController`]: crate::bandwidth::dynamic::DynamicTopologyController
//! [`simulate_scripted_consensus`]: crate::bandwidth::dynamic::simulate_scripted_consensus
//! [`PhaseReport`]: crate::bandwidth::dynamic::PhaseReport

use crate::bandwidth::dynamic::BandwidthTrace;
use crate::util::rng::Xoshiro256pp;
use std::collections::BTreeMap;

/// Heavy-tailed bandwidth distribution used by
/// [`ScenarioEvent::HeavyTailDraw`] to redraw the whole fleet i.i.d.
#[derive(Debug, Clone, PartialEq)]
pub enum TailDist {
    /// Pareto(α, x_m): inverse-CDF sample `x_m · u^(-1/α)`, `u ~ U(0,1)`.
    /// Small α (≤ 2) gives the occasional extremely fast node and a heavy
    /// mass of slow ones — the classic long-tail WAN profile.
    Pareto {
        /// Tail index α > 0 (smaller = heavier tail).
        alpha: f64,
        /// Scale / minimum value x_m > 0 (GB/s).
        xm: f64,
    },
    /// Log-normal: `exp(μ + σ·ξ)`, `ξ ~ N(0,1)` — right-skewed but with all
    /// moments finite, the standard datacenter-bandwidth fit.
    LogNormal {
        /// Location μ of the underlying normal (log GB/s).
        mu: f64,
        /// Scale σ > 0 of the underlying normal.
        sigma: f64,
    },
}

impl TailDist {
    /// Draw one sample.
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        match self {
            TailDist::Pareto { alpha, xm } => {
                let u = (1.0 - rng.next_f64()).max(f64::MIN_POSITIVE);
                xm * u.powf(-1.0 / alpha)
            }
            TailDist::LogNormal { mu, sigma } => (mu + sigma * rng.next_gaussian()).exp(),
        }
    }
}

/// One scripted event. Events fire at the **start** of their phase, after the
/// background drift step (so an explicit `set_bandwidth` wins over drift
/// within its phase).
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioEvent {
    /// Set the multiplicative random-walk drift rate from this phase on:
    /// every later phase transition scales each node's bandwidth by
    /// `exp(σ·ξ)`, `ξ ~ N(0,1)`. `sigma = 0` turns drift off again.
    Drift {
        /// Per-phase log-scale drift rate σ.
        sigma: f64,
    },
    /// Pin one node's bandwidth to an exact value (GB/s).
    SetBandwidth {
        /// Node index.
        node: usize,
        /// New bandwidth in GB/s.
        bw: f64,
    },
    /// Scale a set of nodes' bandwidths by a factor (e.g. co-tenant
    /// interference at `factor < 1`, recovery at `factor > 1`).
    LinkDegrade {
        /// Affected node indices.
        nodes: Vec<usize>,
        /// Multiplicative factor applied to each node's current bandwidth.
        factor: f64,
    },
    /// Node churn: with `rejoin_bw = None` the node leaves (its bandwidth
    /// collapses to the churn floor, so the optimizer routes around it);
    /// with `Some(bw)` it rejoins at that bandwidth.
    NodeChurn {
        /// Node index.
        node: usize,
        /// `None` = leave, `Some(bw)` = rejoin at `bw` GB/s.
        rejoin_bw: Option<f64>,
    },
    /// Emit a labelled stats checkpoint at the end of this phase (consumed by
    /// [`simulate_scripted_consensus`]).
    ///
    /// [`simulate_scripted_consensus`]: crate::bandwidth::dynamic::simulate_scripted_consensus
    ReportStats {
        /// Checkpoint label for reports/CSV.
        label: String,
    },
    /// Redraw **every** node's bandwidth i.i.d. from a heavy-tailed
    /// distribution (clamped like all other updates).
    HeavyTailDraw {
        /// The distribution to draw from.
        dist: TailDist,
    },
    /// Switch the background drift to a *correlated* random walk from this
    /// phase on: each transition scales node i by
    /// `exp(σ·(√ρ·z + √(1−ρ)·ξᵢ))` with a shared factor `z ~ N(0,1)` and
    /// per-node noise `ξᵢ ~ N(0,1)`. `ρ = 1` moves the whole fleet in
    /// lockstep (a shared-backbone congestion event); `ρ = 0` recovers
    /// independent drift. `sigma = 0` turns correlated drift off again.
    CorrelatedDrift {
        /// Per-phase log-scale drift rate σ ≥ 0.
        sigma: f64,
        /// Cross-node correlation ρ ∈ \[0, 1].
        rho: f64,
    },
    /// Network partition: the listed nodes' bandwidths collapse to the churn
    /// floor (effectively unreachable). Their pre-partition bandwidths are
    /// remembered so a later [`ScenarioEvent::Heal`] can restore them.
    Partition {
        /// Nodes cut off by the partition.
        nodes: Vec<usize>,
    },
    /// Coordinated stragglers: scale the listed nodes by `factor` (< 1),
    /// remembering their pre-straggle bandwidths for [`ScenarioEvent::Heal`].
    /// Unlike [`ScenarioEvent::LinkDegrade`] this is a *reversible* episode.
    Straggle {
        /// The straggling nodes.
        nodes: Vec<usize>,
        /// Multiplicative slowdown factor (0 < factor).
        factor: f64,
    },
    /// Heal listed nodes: restore the bandwidth remembered by the most recent
    /// unhealed [`ScenarioEvent::Partition`] / [`ScenarioEvent::Straggle`]
    /// covering them. Nodes with nothing to heal are left untouched.
    Heal {
        /// Nodes to restore.
        nodes: Vec<usize>,
    },
    /// Diurnal load curve from this phase on: every node's bandwidth is
    /// modulated by `m(k) = 1 + a·sin(2π(k−k₀)/T)` (k₀ = this phase), applied
    /// incrementally as `bw ← bw · m(k)/m(k−1)` at each transition so it
    /// composes with drift and scripted events. `amplitude = 0` turns the
    /// modulation off.
    Diurnal {
        /// Peak-to-mean amplitude a ∈ \[0, 1).
        amplitude: f64,
        /// Period in phases (≥ 2).
        period: usize,
    },
}

/// A [`ScenarioEvent`] bound to its phase index.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledEvent {
    /// Phase at which the event fires.
    pub phase: usize,
    /// The event itself.
    pub event: ScenarioEvent,
}

/// Fluent builder for scripted bandwidth scenarios. See the
/// [module docs](self) for an end-to-end example.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    initial: Vec<f64>,
    phase_seconds: f64,
    horizon: Option<usize>,
    lo: f64,
    hi: f64,
    churn_floor: f64,
    cursor: usize,
    events: Vec<ScheduledEvent>,
}

impl ScenarioBuilder {
    /// Start a scenario from per-node initial bandwidths (GB/s). The cursor
    /// starts at phase 0; phase duration defaults to 1 simulated second.
    pub fn new(initial_bw: Vec<f64>) -> ScenarioBuilder {
        assert!(!initial_bw.is_empty(), "scenario needs at least one node");
        ScenarioBuilder {
            initial: initial_bw,
            phase_seconds: 1.0,
            horizon: None,
            lo: 1e-3,
            hi: f64::INFINITY,
            churn_floor: 0.05,
            cursor: 0,
            events: Vec::new(),
        }
    }

    /// Set the simulated duration of every phase (seconds).
    pub fn phase_seconds(mut self, seconds: f64) -> Self {
        assert!(seconds > 0.0, "phase duration must be positive");
        self.phase_seconds = seconds;
        self
    }

    /// Set the scenario horizon (total number of phases). Without an explicit
    /// horizon the trace extends to the last scheduled event; the horizon is
    /// never shorter than that.
    pub fn phases(mut self, phases: usize) -> Self {
        assert!(phases > 0, "scenario needs at least one phase");
        self.horizon = Some(phases);
        self
    }

    /// Clamp all bandwidths (drifted or scripted) to `[lo, hi]` GB/s.
    /// Defaults to `[1e-3, ∞)`; `lo = 0` is permitted for raw traces, but
    /// note the time model divides by `b_min`, so a simulated scenario needs
    /// strictly positive bandwidths.
    pub fn clamp(mut self, lo: f64, hi: f64) -> Self {
        assert!(lo >= 0.0 && hi >= lo, "need 0 <= lo <= hi");
        self.lo = lo;
        self.hi = hi;
        self
    }

    /// Bandwidth assigned to a node that leaves via [`node_churn`]
    /// (default 0.05 GB/s — effectively unreachable, but nonzero so the
    /// Algorithm-1 allocation stays well-defined).
    ///
    /// [`node_churn`]: ScenarioBuilder::node_churn
    pub fn churn_floor(mut self, bw: f64) -> Self {
        assert!(bw > 0.0, "churn floor must be positive");
        self.churn_floor = bw;
        self
    }

    /// Move the cursor: subsequent events attach to phase `k`.
    pub fn at_phase(mut self, k: usize) -> Self {
        self.cursor = k;
        self
    }

    fn push(mut self, event: ScenarioEvent) -> Self {
        self.validate(&event);
        self.events.push(ScheduledEvent {
            phase: self.cursor,
            event,
        });
        self
    }

    /// Schedule an arbitrary [`ScenarioEvent`] at an explicit phase (the
    /// programmatic entry point used by replayed/fuzzed scenario programs —
    /// see [`crate::bandwidth::corpus::ScenarioProgram`]). Applies the same
    /// validation as the typed builder methods; does not move the cursor.
    pub fn event(mut self, phase: usize, event: ScenarioEvent) -> Self {
        self.validate(&event);
        self.events.push(ScheduledEvent { phase, event });
        self
    }

    fn check_node(&self, node: usize) {
        assert!(
            node < self.initial.len(),
            "node {node} out of range (scenario has {} nodes)",
            self.initial.len()
        );
    }

    /// Validation shared by the typed builder methods and [`event`].
    ///
    /// [`event`]: ScenarioBuilder::event
    fn validate(&self, event: &ScenarioEvent) {
        match event {
            ScenarioEvent::Drift { sigma } => {
                assert!(*sigma >= 0.0, "drift sigma must be non-negative");
            }
            ScenarioEvent::SetBandwidth { node, bw } => {
                self.check_node(*node);
                assert!(*bw > 0.0, "bandwidth must be positive");
            }
            ScenarioEvent::LinkDegrade { nodes, factor } => {
                for &i in nodes {
                    self.check_node(i);
                }
                assert!(*factor > 0.0, "degradation factor must be positive");
            }
            ScenarioEvent::NodeChurn { node, rejoin_bw } => {
                self.check_node(*node);
                if let Some(bw) = rejoin_bw {
                    assert!(*bw > 0.0, "rejoin bandwidth must be positive");
                }
            }
            ScenarioEvent::ReportStats { .. } => {}
            ScenarioEvent::HeavyTailDraw { dist } => match dist {
                TailDist::Pareto { alpha, xm } => {
                    assert!(*alpha > 0.0, "pareto alpha must be positive");
                    assert!(*xm > 0.0, "pareto scale must be positive");
                }
                TailDist::LogNormal { mu, sigma } => {
                    assert!(mu.is_finite(), "lognormal mu must be finite");
                    assert!(*sigma > 0.0, "lognormal sigma must be positive");
                }
            },
            ScenarioEvent::CorrelatedDrift { sigma, rho } => {
                assert!(*sigma >= 0.0, "correlated drift sigma must be non-negative");
                assert!((0.0..=1.0).contains(rho), "correlation rho must be in [0,1]");
            }
            ScenarioEvent::Partition { nodes } | ScenarioEvent::Heal { nodes } => {
                assert!(!nodes.is_empty(), "partition/heal needs at least one node");
                for &i in nodes {
                    self.check_node(i);
                }
            }
            ScenarioEvent::Straggle { nodes, factor } => {
                assert!(!nodes.is_empty(), "straggle needs at least one node");
                for &i in nodes {
                    self.check_node(i);
                }
                assert!(*factor > 0.0, "straggle factor must be positive");
            }
            ScenarioEvent::Diurnal { amplitude, period } => {
                assert!(
                    (0.0..1.0).contains(amplitude),
                    "diurnal amplitude must be in [0,1) so the modulator stays positive"
                );
                assert!(*period >= 2, "diurnal period must be at least 2 phases");
            }
        }
    }

    /// Enable random-walk drift with rate `sigma` from the cursor phase on
    /// (see [`ScenarioEvent::Drift`]).
    pub fn drift(self, sigma: f64) -> Self {
        self.push(ScenarioEvent::Drift { sigma })
    }

    /// Pin `node`'s bandwidth to `bw` GB/s at the cursor phase.
    pub fn set_bandwidth(self, node: usize, bw: f64) -> Self {
        self.push(ScenarioEvent::SetBandwidth { node, bw })
    }

    /// Scale `nodes`' bandwidths by `factor` at the cursor phase.
    pub fn link_degrade(self, nodes: &[usize], factor: f64) -> Self {
        self.push(ScenarioEvent::LinkDegrade {
            nodes: nodes.to_vec(),
            factor,
        })
    }

    /// Node churn at the cursor phase: `None` = node leaves (bandwidth drops
    /// to the churn floor), `Some(bw)` = node rejoins at `bw` GB/s (never
    /// below the churn floor).
    pub fn node_churn(self, node: usize, rejoin_bw: Option<f64>) -> Self {
        self.push(ScenarioEvent::NodeChurn { node, rejoin_bw })
    }

    /// Schedule a labelled stats checkpoint at the end of the cursor phase.
    pub fn report_stats(self, label: &str) -> Self {
        self.push(ScenarioEvent::ReportStats {
            label: label.to_string(),
        })
    }

    /// Redraw every node's bandwidth from Pareto(α, x_m) at the cursor phase.
    pub fn pareto_draw(self, alpha: f64, xm: f64) -> Self {
        self.push(ScenarioEvent::HeavyTailDraw {
            dist: TailDist::Pareto { alpha, xm },
        })
    }

    /// Redraw every node's bandwidth from LogNormal(μ, σ) at the cursor phase.
    pub fn lognormal_draw(self, mu: f64, sigma: f64) -> Self {
        self.push(ScenarioEvent::HeavyTailDraw {
            dist: TailDist::LogNormal { mu, sigma },
        })
    }

    /// Enable correlated drift (rate `sigma`, correlation `rho`) from the
    /// cursor phase on (see [`ScenarioEvent::CorrelatedDrift`]).
    pub fn correlated_drift(self, sigma: f64, rho: f64) -> Self {
        self.push(ScenarioEvent::CorrelatedDrift { sigma, rho })
    }

    /// Partition `nodes` off the network at the cursor phase (bandwidths drop
    /// to the churn floor; [`heal`] restores them).
    ///
    /// [`heal`]: ScenarioBuilder::heal
    pub fn partition(self, nodes: &[usize]) -> Self {
        self.push(ScenarioEvent::Partition {
            nodes: nodes.to_vec(),
        })
    }

    /// Turn `nodes` into coordinated stragglers (×`factor`) at the cursor
    /// phase; [`heal`] restores their pre-straggle bandwidths.
    ///
    /// [`heal`]: ScenarioBuilder::heal
    pub fn straggle(self, nodes: &[usize], factor: f64) -> Self {
        self.push(ScenarioEvent::Straggle {
            nodes: nodes.to_vec(),
            factor,
        })
    }

    /// Heal `nodes` at the cursor phase (restore partition/straggle state).
    pub fn heal(self, nodes: &[usize]) -> Self {
        self.push(ScenarioEvent::Heal {
            nodes: nodes.to_vec(),
        })
    }

    /// Enable a diurnal load curve (amplitude `a`, period `T` phases) from
    /// the cursor phase on (see [`ScenarioEvent::Diurnal`]).
    pub fn diurnal(self, amplitude: f64, period: usize) -> Self {
        self.push(ScenarioEvent::Diurnal { amplitude, period })
    }

    /// Events scheduled so far (insertion order).
    pub fn events(&self) -> &[ScheduledEvent] {
        &self.events
    }

    /// Compile with a fixed drift seed. Walks phases in order carrying the
    /// current bandwidth vector: each transition applies the active i.i.d.
    /// drift, then the active correlated drift, then the active diurnal
    /// modulation (in that fixed order), then the phase's scripted events in
    /// schedule order.
    pub fn compile(self, seed: u64) -> CompiledScenario {
        let min_horizon = self
            .events
            .iter()
            .map(|e| e.phase + 1)
            .max()
            .unwrap_or(1);
        let horizon = self.horizon.unwrap_or(min_horizon).max(min_horizon);

        let mut events = self.events;
        events.sort_by_key(|e| e.phase); // stable: same-phase order preserved

        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut bw = self.initial;
        let mut sigma = 0.0f64;
        // Correlated drift state: (σ, ρ); σ = 0 ⇒ inactive.
        let mut corr = (0.0f64, 0.0f64);
        // Diurnal state: (amplitude, period, anchor phase); a = 0 ⇒ inactive.
        let mut diurnal = (0.0f64, 2usize, 0usize);
        // Pre-partition/straggle bandwidths, restored by Heal. `or_insert`
        // keeps the *first* saved value when episodes overlap, so a heal
        // always restores the pre-episode state.
        let mut saved: BTreeMap<usize, f64> = BTreeMap::new();
        let mut phases = Vec::with_capacity(horizon);
        let mut reports = Vec::new();
        for k in 0..horizon {
            if k > 0 && sigma > 0.0 {
                for b in bw.iter_mut() {
                    *b = (*b * (sigma * rng.next_gaussian()).exp()).clamp(self.lo, self.hi);
                }
            }
            if k > 0 && corr.0 > 0.0 {
                let (s, rho) = corr;
                let z = rng.next_gaussian();
                for b in bw.iter_mut() {
                    let xi = rng.next_gaussian();
                    let step = s * (rho.sqrt() * z + (1.0 - rho).sqrt() * xi);
                    *b = (*b * step.exp()).clamp(self.lo, self.hi);
                }
            }
            if k > 0 && diurnal.0 > 0.0 {
                let (a, period, k0) = diurnal;
                let m = |phase: usize| -> f64 {
                    let t = (phase - k0) as f64 / period as f64;
                    1.0 + a * (2.0 * std::f64::consts::PI * t).sin()
                };
                // k ≥ k0 + 1 here: the modulator anchors at its event phase.
                let ratio = m(k) / m(k - 1);
                for b in bw.iter_mut() {
                    *b = (*b * ratio).clamp(self.lo, self.hi);
                }
            }
            for ev in events.iter().filter(|e| e.phase == k) {
                match &ev.event {
                    ScenarioEvent::Drift { sigma: s } => sigma = *s,
                    ScenarioEvent::SetBandwidth { node, bw: v } => {
                        bw[*node] = v.clamp(self.lo, self.hi);
                    }
                    ScenarioEvent::LinkDegrade { nodes, factor } => {
                        for &i in nodes {
                            bw[i] = (bw[i] * factor).clamp(self.lo, self.hi);
                        }
                    }
                    ScenarioEvent::NodeChurn { node, rejoin_bw } => {
                        bw[*node] = match rejoin_bw {
                            // The churn floor is honored on rejoin too: a
                            // node cannot come back weaker than a departed
                            // one, or the time model's b_min goes degenerate.
                            Some(v) => v.max(self.churn_floor).clamp(self.lo, self.hi),
                            None => self.churn_floor,
                        };
                    }
                    ScenarioEvent::ReportStats { label } => {
                        reports.push((k, label.clone()));
                    }
                    ScenarioEvent::HeavyTailDraw { dist } => {
                        for b in bw.iter_mut() {
                            *b = dist.sample(&mut rng).clamp(self.lo, self.hi);
                        }
                    }
                    ScenarioEvent::CorrelatedDrift { sigma: s, rho } => corr = (*s, *rho),
                    ScenarioEvent::Partition { nodes } => {
                        for &i in nodes {
                            saved.entry(i).or_insert(bw[i]);
                            bw[i] = self.churn_floor;
                        }
                    }
                    ScenarioEvent::Straggle { nodes, factor } => {
                        for &i in nodes {
                            saved.entry(i).or_insert(bw[i]);
                            bw[i] = (bw[i] * factor).clamp(self.lo, self.hi);
                        }
                    }
                    ScenarioEvent::Heal { nodes } => {
                        for &i in nodes {
                            if let Some(v) = saved.remove(&i) {
                                bw[i] = v.clamp(self.lo, self.hi);
                            }
                        }
                    }
                    ScenarioEvent::Diurnal { amplitude, period } => {
                        diurnal = (*amplitude, *period, k);
                    }
                }
            }
            phases.push(bw.clone());
        }
        CompiledScenario {
            trace: BandwidthTrace {
                phases,
                phase_seconds: self.phase_seconds,
            },
            reports,
            events,
        }
    }

    /// Compile with the default drift seed 0. Scenarios without [`drift`]
    /// events are fully deterministic, so the seed is irrelevant for them.
    ///
    /// [`drift`]: ScenarioBuilder::drift
    pub fn build(self) -> CompiledScenario {
        self.compile(0)
    }
}

/// A compiled scenario: the bandwidth trace plus the event/report schedule.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    /// Piecewise-constant per-node bandwidth trace (one row per phase).
    pub trace: BandwidthTrace,
    /// `(phase, label)` checkpoints from [`ScenarioBuilder::report_stats`],
    /// in phase order.
    pub reports: Vec<(usize, String)>,
    /// The full event schedule, sorted by phase (stable).
    pub events: Vec<ScheduledEvent>,
}

impl CompiledScenario {
    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.trace.num_nodes()
    }

    /// Number of phases.
    pub fn num_phases(&self) -> usize {
        self.trace.phases.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_compile_in_phase_order() {
        // Events scheduled out of order still apply phase-by-phase.
        let s = ScenarioBuilder::new(vec![10.0; 4])
            .at_phase(3)
            .set_bandwidth(0, 1.0)
            .at_phase(1)
            .set_bandwidth(0, 5.0)
            .build();
        assert_eq!(s.num_phases(), 4);
        assert_eq!(s.trace.phases[0][0], 10.0);
        assert_eq!(s.trace.phases[1][0], 5.0);
        assert_eq!(s.trace.phases[2][0], 5.0); // persists until next event
        assert_eq!(s.trace.phases[3][0], 1.0);
        // Schedule is sorted by phase after compile.
        assert!(s.events.windows(2).all(|w| w[0].phase <= w[1].phase));
    }

    #[test]
    fn horizon_extends_to_last_event() {
        let s = ScenarioBuilder::new(vec![1.0]).at_phase(7).report_stats("x").build();
        assert_eq!(s.num_phases(), 8);
        let s2 = ScenarioBuilder::new(vec![1.0]).phases(3).build();
        assert_eq!(s2.num_phases(), 3);
    }

    #[test]
    fn degrade_churn_and_clamp() {
        let s = ScenarioBuilder::new(vec![8.0; 4])
            .clamp(0.5, 10.0)
            .phases(4)
            .at_phase(1)
            .link_degrade(&[2, 3], 0.01) // would be 0.08, clamped to 0.5
            .at_phase(2)
            .node_churn(0, None)
            .at_phase(3)
            .node_churn(0, Some(6.0))
            .build();
        assert_eq!(s.trace.phases[1][2], 0.5);
        assert_eq!(s.trace.phases[1][3], 0.5);
        assert_eq!(s.trace.phases[1][0], 8.0);
        assert_eq!(s.trace.phases[2][0], 0.05); // churn floor, below clamp by design
        assert_eq!(s.trace.phases[3][0], 6.0);
    }

    #[test]
    fn drift_is_seeded_and_clamped() {
        let a = ScenarioBuilder::new(vec![5.0; 6])
            .phases(10)
            .clamp(1.0, 20.0)
            .drift(0.4)
            .compile(9);
        let b = ScenarioBuilder::new(vec![5.0; 6])
            .phases(10)
            .clamp(1.0, 20.0)
            .drift(0.4)
            .compile(9);
        assert_eq!(a.trace.phases, b.trace.phases, "same seed, same trace");
        assert!(a.trace.phases.iter().flatten().all(|&x| (1.0..=20.0).contains(&x)));
        // Drift actually moves the values.
        assert_ne!(a.trace.phases[0], a.trace.phases[9]);
        let c = ScenarioBuilder::new(vec![5.0; 6])
            .phases(10)
            .clamp(1.0, 20.0)
            .drift(0.4)
            .compile(10);
        assert_ne!(a.trace.phases, c.trace.phases, "different seed, different trace");
    }

    #[test]
    fn drift_can_be_turned_off() {
        let s = ScenarioBuilder::new(vec![5.0; 2])
            .phases(6)
            .drift(0.5)
            .at_phase(3)
            .drift(0.0)
            .compile(4);
        // After phase 3 the values freeze.
        assert_eq!(s.trace.phases[4], s.trace.phases[3]);
        assert_eq!(s.trace.phases[5], s.trace.phases[3]);
        assert_ne!(s.trace.phases[0], s.trace.phases[3]);
    }

    #[test]
    fn reports_are_collected_in_phase_order() {
        let s = ScenarioBuilder::new(vec![1.0; 2])
            .at_phase(4)
            .report_stats("late")
            .at_phase(1)
            .report_stats("early")
            .build();
        assert_eq!(
            s.reports,
            vec![(1, "early".to_string()), (4, "late".to_string())]
        );
    }

    #[test]
    fn heavy_tail_draws_are_seeded_and_clamped() {
        let mk = |seed| {
            ScenarioBuilder::new(vec![5.0; 16])
                .phases(3)
                .clamp(0.5, 40.0)
                .at_phase(1)
                .pareto_draw(1.5, 2.0)
                .compile(seed)
        };
        let (a, b, c) = (mk(3), mk(3), mk(4));
        assert_eq!(a.trace.phases, b.trace.phases, "same seed, same draw");
        assert_ne!(a.trace.phases[1], c.trace.phases[1], "seed matters");
        assert_eq!(a.trace.phases[0], vec![5.0; 16], "draw fires at its phase");
        assert!(a.trace.phases[1].iter().all(|&x| (0.5..=40.0).contains(&x)));
        // Pareto(1.5, 2.0) redraw actually moves the fleet off 5.0.
        assert!(a.trace.phases[1].iter().any(|&x| (x - 5.0).abs() > 1e-9));

        let ln = ScenarioBuilder::new(vec![5.0; 8])
            .phases(2)
            .at_phase(1)
            .lognormal_draw(2.0, 0.5)
            .compile(7);
        assert!(ln.trace.phases[1].iter().all(|&x| x > 0.0));
        assert_ne!(ln.trace.phases[0], ln.trace.phases[1]);
    }

    #[test]
    fn correlated_drift_moves_nodes_together() {
        // At ρ = 1 every node shares the same multiplicative step, so the
        // ratios bw_i(k)/bw_i(0) are identical across nodes.
        let s = ScenarioBuilder::new(vec![4.0; 6])
            .phases(5)
            .correlated_drift(0.3, 1.0)
            .compile(11);
        for k in 1..5 {
            let r0 = s.trace.phases[k][0] / s.trace.phases[0][0];
            for i in 1..6 {
                let ri = s.trace.phases[k][i] / s.trace.phases[0][i];
                assert!((ri - r0).abs() < 1e-12, "phase {k} node {i}: {ri} vs {r0}");
            }
        }
        // ρ = 0 decorrelates: some node must deviate from node 0's ratio.
        let s0 = ScenarioBuilder::new(vec![4.0; 6])
            .phases(5)
            .correlated_drift(0.3, 0.0)
            .compile(11);
        let r0 = s0.trace.phases[4][0] / s0.trace.phases[0][0];
        assert!((1..6).any(|i| {
            let ri = s0.trace.phases[4][i] / s0.trace.phases[0][i];
            (ri - r0).abs() > 1e-9
        }));
    }

    #[test]
    fn partition_heals_back_to_pre_partition_state() {
        let s = ScenarioBuilder::new(vec![9.76, 9.76, 3.25, 3.25])
            .phases(5)
            .at_phase(1)
            .partition(&[2, 3])
            .at_phase(3)
            .heal(&[2, 3])
            .build();
        assert_eq!(s.trace.phases[1][2], 0.05, "partitioned at churn floor");
        assert_eq!(s.trace.phases[1][3], 0.05);
        assert_eq!(s.trace.phases[1][0], 9.76, "unpartitioned side untouched");
        assert_eq!(s.trace.phases[3][2], 3.25, "heal restores saved bandwidth");
        assert_eq!(s.trace.phases[4][3], 3.25);
    }

    #[test]
    fn straggle_is_reversible_and_heal_is_idempotent() {
        let s = ScenarioBuilder::new(vec![8.0; 3])
            .phases(6)
            .at_phase(1)
            .straggle(&[0, 1], 0.1)
            .at_phase(2)
            .straggle(&[0], 0.5) // stacked episode keeps the first saved value
            .at_phase(4)
            .heal(&[0, 1, 2]) // node 2 has nothing to heal: no-op
            .at_phase(5)
            .heal(&[0]) // already healed: no-op
            .build();
        assert!((s.trace.phases[1][0] - 0.8).abs() < 1e-12);
        assert!((s.trace.phases[2][0] - 0.4).abs() < 1e-12);
        assert_eq!(s.trace.phases[4][0], 8.0);
        assert_eq!(s.trace.phases[4][1], 8.0);
        assert_eq!(s.trace.phases[4][2], 8.0);
        assert_eq!(s.trace.phases[5][0], 8.0);
    }

    #[test]
    fn diurnal_modulation_is_periodic_and_positive() {
        let s = ScenarioBuilder::new(vec![10.0; 2])
            .phases(9)
            .diurnal(0.5, 4)
            .build();
        assert!(s.trace.phases.iter().flatten().all(|&b| b > 0.0));
        // One full period returns to the anchor value (no drift on top).
        assert!((s.trace.phases[4][0] - 10.0).abs() < 1e-9);
        assert!((s.trace.phases[8][0] - 10.0).abs() < 1e-9);
        // ...but mid-period the load curve visibly moves the bandwidth.
        assert!((s.trace.phases[1][0] - 10.0).abs() > 1.0);
        // Deterministic: no RNG draws are consumed by the modulator.
        let t = ScenarioBuilder::new(vec![10.0; 2])
            .phases(9)
            .diurnal(0.5, 4)
            .compile(99);
        assert_eq!(s.trace.phases, t.trace.phases);
    }

    #[test]
    fn rejoin_below_churn_floor_is_lifted_to_the_floor() {
        let s = ScenarioBuilder::new(vec![9.76; 2])
            .phases(3)
            .at_phase(1)
            .node_churn(1, None)
            .at_phase(2)
            .node_churn(1, Some(0.01)) // below the 0.05 default floor
            .build();
        assert_eq!(s.trace.phases[2][1], 0.05);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn event_entry_point_validates_like_the_typed_methods() {
        let _ = ScenarioBuilder::new(vec![1.0; 2]).event(
            0,
            ScenarioEvent::Partition { nodes: vec![7] },
        );
    }
}
