//! Scenario fuzzer: generates random [`ScenarioProgram`]s, runs them through
//! [`simulate_scripted_consensus`] (both the static and the adaptive
//! [`DynamicTopologyController`] arm), checks simulation invariants, and on a
//! violation greedily *shrinks* the program with
//! [`crate::util::prop::shrink_greedy`] before dumping it as a replayable
//! `*.scenario` file.
//!
//! Driven by `batopo fuzz scenarios` (see `docs/SCENARIOS.md`); a dump can be
//! re-checked with `batopo fuzz replay <file>`.
//!
//! [`simulate_scripted_consensus`]: crate::bandwidth::dynamic::simulate_scripted_consensus
//! [`DynamicTopologyController`]: crate::bandwidth::dynamic::DynamicTopologyController

use crate::bandwidth::corpus::ScenarioProgram;
use crate::bandwidth::dynamic::{simulate_scripted_consensus, DynamicPolicy, ScriptedRun};
use crate::util::prop::{panic_message, shrink_greedy};
use crate::util::rng::Xoshiro256pp;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Which invariant suite to check on every fuzzed program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Invariant {
    /// The invariants every legal scenario must satisfy: no panic anywhere in
    /// compile → optimize → simulate, finite times and errors, non-increasing
    /// consensus error across checkpoints, and monotone counters
    /// (rounds/switches/reopt-failures/sim-time). This is the suite CI runs
    /// and it is expected to pass.
    Core,
    /// [`Invariant::Core`] **plus** "every checkpointed phase executes at
    /// least one gossip round". This is deliberately *false* for outage-style
    /// scenarios (a partitioned fleet at the churn floor has a round time
    /// longer than the phase), so it serves as the seeded known-bad invariant
    /// exercising the shrink-and-dump path end to end.
    EveryPhaseGossips,
}

impl Invariant {
    /// CLI name of the invariant suite.
    pub fn name(self) -> &'static str {
        match self {
            Invariant::Core => "core",
            Invariant::EveryPhaseGossips => "every-phase-gossips",
        }
    }

    /// Parse a CLI name.
    pub fn by_name(name: &str) -> Option<Invariant> {
        match name {
            "core" => Some(Invariant::Core),
            "every-phase-gossips" => Some(Invariant::EveryPhaseGossips),
            _ => None,
        }
    }
}

/// Fuzzer configuration (the `batopo fuzz scenarios` flags).
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Number of random programs to generate and check.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
    /// Invariant suite to check.
    pub invariant: Invariant,
    /// Quick mode: shorter scenario horizons.
    pub quick: bool,
    /// Directory for `fuzz_case*.scenario` dumps of shrunk failures.
    pub out_dir: PathBuf,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        FuzzConfig {
            cases: 64,
            seed: 0xF022,
            invariant: Invariant::Core,
            quick: false,
            out_dir: PathBuf::from("fuzz-out"),
        }
    }
}

/// One invariant violation, minimized and dumped.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    /// Fuzz case index (seed = config seed + case).
    pub case: usize,
    /// The violation message from the *shrunk* program.
    pub violation: String,
    /// Event count of the original failing program.
    pub original_events: usize,
    /// Event count after shrinking (≤ original).
    pub shrunk_events: usize,
    /// Where the replayable dump was written.
    pub dump_path: PathBuf,
    /// The shrunk program itself.
    pub program: ScenarioProgram,
}

/// Aggregate fuzzing outcome.
#[derive(Debug)]
pub struct FuzzOutcome {
    /// Cases executed.
    pub cases: usize,
    /// Violations found (empty = all invariants held).
    pub failures: Vec<FuzzFailure>,
}

/// Simulation policy used for fuzzed programs: generous edge budget so the
/// optimizer is feasible for any fuzzed fleet size, tight hysteresis so the
/// adaptive arm actually adapts, quick optimizer budgets.
fn fuzz_policy(program: &ScenarioProgram) -> DynamicPolicy {
    let n = program.num_nodes();
    DynamicPolicy {
        r: (3 * n / 2).max(n),
        hysteresis: 1.05,
        quick: true,
        switch_cost: 0.05,
        seed: program.seed,
        candidates: None,
    }
}

/// Check one program against an invariant suite. `Err` carries a one-line
/// violation message; panics anywhere in compile/optimize/simulate are caught
/// and reported as `panic: <message>` violations.
pub fn check_program(program: &ScenarioProgram, invariant: Invariant) -> Result<(), String> {
    let p = program.clone();
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        check_inner(&p, invariant)
    }))
    .unwrap_or_else(|payload| Err(format!("panic: {}", panic_message(payload.as_ref()))))
}

fn check_inner(program: &ScenarioProgram, invariant: Invariant) -> Result<(), String> {
    let scenario = program.compile();
    let policy = fuzz_policy(program);
    for adapt in [false, true] {
        let arm = if adapt { "adaptive" } else { "static" };
        let run = simulate_scripted_consensus(&scenario, policy.clone(), adapt, program.seed);
        check_run(&run, invariant).map_err(|e| format!("{arm} arm: {e}"))?;
    }
    Ok(())
}

fn check_run(run: &ScriptedRun, invariant: Invariant) -> Result<(), String> {
    let out = &run.outcome;
    if !out.final_log_error.is_finite() {
        return Err(format!("final_log_error is {}", out.final_log_error));
    }
    if out.final_log_error > 1e-6 {
        return Err(format!(
            "consensus error grew: final log10 error {} > 0",
            out.final_log_error
        ));
    }
    if let Some(t) = out.time_to_target {
        if !t.is_finite() || t < 0.0 {
            return Err(format!("time_to_target {t} is not a finite non-negative time"));
        }
    }
    for r in &run.reports {
        if !r.log_error.is_finite() {
            return Err(format!("phase {} log_error is {}", r.phase, r.log_error));
        }
        if !r.sim_time.is_finite() || r.sim_time <= 0.0 {
            return Err(format!("phase {} sim_time is {}", r.phase, r.sim_time));
        }
        if r.b_min.is_nan() || r.b_min < 0.0 {
            return Err(format!("phase {} b_min is {}", r.phase, r.b_min));
        }
    }
    for w in run.reports.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        if b.log_error > a.log_error + 1e-6 {
            return Err(format!(
                "consensus error not monotone: phase {} log10 error {} > phase {} log10 error {}",
                b.phase, b.log_error, a.phase, a.log_error
            ));
        }
        for (what, x, y) in [
            ("rounds", a.rounds, b.rounds),
            ("switches", a.switches, b.switches),
            ("reopt_failures", a.reopt_failures, b.reopt_failures),
        ] {
            if y < x {
                return Err(format!(
                    "{what} decreased between phases {} and {}: {x} -> {y}",
                    a.phase, b.phase
                ));
            }
        }
        if b.sim_time < a.sim_time {
            return Err(format!(
                "sim_time decreased between phases {} and {}",
                a.phase, b.phase
            ));
        }
    }
    if invariant == Invariant::EveryPhaseGossips {
        if let Some(first) = run.reports.first() {
            if first.rounds == 0 {
                return Err(format!(
                    "phase {} checkpoint saw zero gossip rounds",
                    first.phase
                ));
            }
        }
        for w in run.reports.windows(2) {
            // Same-phase checkpoints share a round count; across phases the
            // count must strictly grow.
            if w[1].phase > w[0].phase && w[1].rounds == w[0].rounds {
                return Err(format!(
                    "no gossip rounds between phase {} and phase {} checkpoints",
                    w[0].phase, w[1].phase
                ));
            }
        }
    }
    Ok(())
}

/// Minimize a failing program: greedy shrinking over
/// [`ScenarioProgram::shrink_moves`] with [`ScenarioProgram::size`] as the
/// measure, accepting only candidates that still violate `invariant`.
pub fn shrink_failing(program: &ScenarioProgram, invariant: Invariant) -> ScenarioProgram {
    shrink_greedy(
        program.clone(),
        &|p: &ScenarioProgram| p.size(),
        &|p: &ScenarioProgram| p.shrink_moves(),
        &|p: &ScenarioProgram| check_program(p, invariant).is_err(),
        400,
    )
}

/// Run the fuzzer: `cfg.cases` random programs, each checked against
/// `cfg.invariant`; every violation is shrunk and dumped to
/// `cfg.out_dir/fuzz_case<i>.scenario`.
pub fn fuzz_scenarios(cfg: &FuzzConfig) -> std::io::Result<FuzzOutcome> {
    std::fs::create_dir_all(&cfg.out_dir)?;
    let mut failures = Vec::new();
    for case in 0..cfg.cases {
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed.wrapping_add(case as u64));
        let program = ScenarioProgram::random(&mut rng, cfg.quick);
        let Err(original_violation) = check_program(&program, cfg.invariant) else {
            continue;
        };
        let shrunk = shrink_failing(&program, cfg.invariant);
        let violation = check_program(&shrunk, cfg.invariant).err();
        let violation = violation.unwrap_or(original_violation);
        let dump_path = cfg.out_dir.join(format!("fuzz_case{case}.scenario"));
        let mut file = std::fs::File::create(&dump_path)?;
        writeln!(file, "# fuzz case {case} (base seed {})", cfg.seed)?;
        writeln!(file, "# invariant: {}", cfg.invariant.name())?;
        writeln!(file, "# violation: {}", violation.replace('\n', " "))?;
        writeln!(
            file,
            "# shrunk from {} events to {}",
            program.events.len(),
            shrunk.events.len()
        )?;
        file.write_all(shrunk.dump().as_bytes())?;
        failures.push(FuzzFailure {
            case,
            violation,
            original_events: program.events.len(),
            shrunk_events: shrunk.events.len(),
            dump_path,
            program: shrunk,
        });
    }
    Ok(FuzzOutcome {
        cases: cfg.cases,
        failures,
    })
}

/// Recover the invariant suite a fuzz dump was minimized against, from the
/// `# invariant: <name>` comment [`fuzz_scenarios`] writes at the top of every
/// dump. Returns `None` when the file has no such comment (hand-written
/// scenario) or the name is unknown; `batopo fuzz replay` uses this to default
/// `--invariant` so CI can re-check a dump without knowing its provenance.
pub fn invariant_from_dump(path: &Path) -> Option<Invariant> {
    let text = std::fs::read_to_string(path).ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# invariant:") {
            return Invariant::by_name(rest.trim());
        }
    }
    None
}

/// Replay a `*.scenario` dump: parse it and re-check `invariant`. Returns the
/// parsed program plus `Some(violation)` when the invariant still fails,
/// `None` when it now holds.
pub fn replay(
    path: &Path,
    invariant: Invariant,
) -> Result<(ScenarioProgram, Option<String>), String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    let program = ScenarioProgram::parse(&text)
        .map_err(|e| format!("cannot parse {}: {e}", path.display()))?;
    let violation = check_program(&program, invariant).err();
    Ok((program, violation))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::scenario_dsl::{ScenarioEvent, ScheduledEvent};

    /// A known-bad program for `EveryPhaseGossips`: a full-fleet partition at
    /// the churn floor makes the round time (~2.9 s at 0.05 GB/s) exceed the
    /// 1.5 s phase, so checkpoints during the partition see no new rounds.
    fn known_bad_program() -> ScenarioProgram {
        let n = 6;
        ScenarioProgram {
            initial: vec![9.76; n],
            phases: 3,
            phase_seconds: 1.5,
            clamp: (1e-3, f64::INFINITY),
            churn_floor: 0.05,
            seed: 13,
            events: vec![
                ScheduledEvent {
                    phase: 1,
                    event: ScenarioEvent::Partition {
                        nodes: (0..n).collect(),
                    },
                },
                ScheduledEvent {
                    phase: 0,
                    event: ScenarioEvent::ReportStats {
                        label: "phase 0".to_string(),
                    },
                },
                ScheduledEvent {
                    phase: 1,
                    event: ScenarioEvent::ReportStats {
                        label: "phase 1".to_string(),
                    },
                },
                ScheduledEvent {
                    phase: 2,
                    event: ScenarioEvent::ReportStats {
                        label: "phase 2".to_string(),
                    },
                },
            ],
        }
    }

    #[test]
    fn core_invariant_holds_on_the_known_bad_program() {
        // The outage is legal behavior: core invariants must pass…
        check_program(&known_bad_program(), Invariant::Core).expect("core should hold");
        // …while the stricter gossip invariant correctly fails.
        let err = check_program(&known_bad_program(), Invariant::EveryPhaseGossips)
            .expect_err("every-phase-gossips should fail");
        assert!(err.contains("gossip"), "unexpected violation: {err}");
    }

    #[test]
    fn shrinking_the_known_bad_program_keeps_it_failing_and_smaller() {
        let original = known_bad_program();
        let shrunk = shrink_failing(&original, Invariant::EveryPhaseGossips);
        assert!(
            shrunk.events.len() < original.events.len(),
            "shrunk {} events vs original {}",
            shrunk.events.len(),
            original.events.len()
        );
        assert!(shrunk.size() < original.size());
        assert!(
            check_program(&shrunk, Invariant::EveryPhaseGossips).is_err(),
            "shrunk program no longer fails"
        );
        // The dump of the shrunk program round-trips and still fails.
        let reparsed = ScenarioProgram::parse(&shrunk.dump()).expect("dump parses");
        assert_eq!(reparsed, shrunk);
        assert!(check_program(&reparsed, Invariant::EveryPhaseGossips).is_err());
    }

    #[test]
    fn invariant_names_roundtrip() {
        for inv in [Invariant::Core, Invariant::EveryPhaseGossips] {
            assert_eq!(Invariant::by_name(inv.name()), Some(inv));
        }
        assert_eq!(Invariant::by_name("bogus"), None);
    }
}
