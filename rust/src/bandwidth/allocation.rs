//! Algorithm 1 of the paper: **Bandwidth-Aware Edge-Capacity Allocation**.
//!
//! Given per-node bandwidths `b`, a total edge budget `r` and per-node edge
//! caps `ē`, determine (i) the *unit bandwidth* `b_unit` — the minimum
//! bandwidth any edge will see — and (ii) the number of edges `e_i` to allot
//! to each node, maximizing `b_unit` subject to hitting the edge budget.
//! Faster nodes receive proportionally more edges, so no single slow link
//! throttles the synchronization round.

/// Result of the allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocationResult {
    /// Minimum per-edge bandwidth achieved.
    pub b_unit: f64,
    /// Edges allotted per node (`Σ e_i = 2r`).
    pub edges_per_node: Vec<usize>,
}

/// Allocation failure modes.
#[derive(Debug, PartialEq)]
pub enum AllocationError {
    /// The caps cannot host the requested edge budget.
    BudgetUnreachable {
        /// Requested edge budget.
        r: usize,
        /// Maximum edges the caps admit.
        max: usize,
    },
    /// Malformed input (too few nodes, bad lengths, non-positive bandwidth).
    Invalid(String),
}

impl std::fmt::Display for AllocationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocationError::BudgetUnreachable { r, max } => write!(
                f,
                "edge budget r={r} cannot be reached: caps admit at most {max} edges"
            ),
            AllocationError::Invalid(msg) => write!(f, "invalid input: {msg}"),
        }
    }
}

impl std::error::Error for AllocationError {}

/// Algorithm 1. `bw[i] > 0` is node i's bandwidth, `r` the edge budget,
/// `caps[i]` the max edges on node i (use `n-1` for "no cap").
pub fn allocate_edge_capacity(
    bw: &[f64],
    r: usize,
    caps: &[usize],
) -> Result<AllocationResult, AllocationError> {
    let n = bw.len();
    if n < 2 {
        return Err(AllocationError::Invalid("need at least 2 nodes".into()));
    }
    if caps.len() != n {
        return Err(AllocationError::Invalid("caps length mismatch".into()));
    }
    if bw.iter().any(|&b| !(b > 0.0)) {
        return Err(AllocationError::Invalid("bandwidths must be positive".into()));
    }
    // The caps bound the total number of edge endpoints.
    let max_edges = caps.iter().sum::<usize>() / 2;
    if r > max_edges {
        return Err(AllocationError::BudgetUnreachable { r, max: max_edges });
    }

    // Line 1: initialize with the most conservative unit bandwidth.
    let mut b_unit = bw.iter().cloned().fold(f64::INFINITY, f64::min);
    let assign = |b_unit: f64| -> Vec<usize> {
        bw.iter()
            .zip(caps)
            // The relative epsilon guards the exact-division case
            // floor(b_i / (b_i/(e_i+1))) — mathematically e_i+1 but prone to
            // rounding down to e_i in floating point, which would stall the
            // refinement loop.
            .map(|(&bi, &cap)| ((bi / b_unit * (1.0 + 1e-12)).floor() as usize).min(cap))
            .collect()
    };
    let mut e = assign(b_unit);
    let count = |e: &[usize]| e.iter().sum::<usize>(); // in endpoint units (2·edges)

    // Lines 2–5: lower b_unit until the budget is reachable. Each pass picks
    // the largest b_unit that grants at least one more edge somewhere.
    let mut guard = 0usize;
    while count(&e) < 2 * r {
        guard += 1;
        if guard > 10 * n * n + 1000 {
            return Err(AllocationError::Invalid(
                "allocation failed to converge".into(),
            ));
        }
        // b_unit = max_i b_i / (e_i + 1) over nodes that can still grow.
        let next = bw
            .iter()
            .zip(&e)
            .zip(caps)
            .filter(|((_, &ei), &cap)| ei < cap)
            .map(|((&bi, &ei), _)| bi / (ei + 1) as f64)
            .fold(f64::NEG_INFINITY, f64::max);
        if !next.is_finite() {
            // Nobody can grow — but we checked max_edges ≥ r, so caps bind at
            // a finite count ≥ 2r only if floor() lost endpoints; force caps.
            // (The returned b_unit is recomputed from the final assignment.)
            e = caps.to_vec();
            break;
        }
        b_unit = next;
        e = assign(b_unit);
    }

    // Lines 6–8: trim overshoot by removing edges from the largest-e node.
    while count(&e) > 2 * r {
        let (imax, _) = e
            .iter()
            .enumerate()
            .max_by_key(|&(_, &ei)| ei)
            .expect("nonempty");
        e[imax] -= 1;
    }
    if count(&e) < 2 * r {
        // Odd-total parity or cap-forcing left us short of the exact target;
        // top up on nodes with headroom, preferring the largest bandwidth per
        // edge so b_unit degrades least.
        let mut guard = 0usize;
        while count(&e) < 2 * r {
            guard += 1;
            if guard > 4 * r + 8 {
                return Err(AllocationError::BudgetUnreachable {
                    r,
                    max: count(&e) / 2,
                });
            }
            let cand = (0..n)
                .filter(|&i| e[i] < caps[i])
                .max_by(|&a, &b| {
                    (bw[a] / (e[a] + 1) as f64)
                        .partial_cmp(&(bw[b] / (e[b] + 1) as f64))
                        .unwrap()
                });
            match cand {
                Some(i) => e[i] += 1,
                None => {
                    return Err(AllocationError::BudgetUnreachable {
                        r,
                        max: count(&e) / 2,
                    })
                }
            }
        }
    }

    // Graphicality repair: the trim step can emit degree sequences no simple
    // graph realizes (e.g. (5,5,5,5,1,1,1,1)); shift endpoints from the
    // most-loaded node to the least-loaded node with headroom until the
    // Erdős–Gallai conditions hold. This trades a little unit bandwidth for
    // realizability — without it the downstream topology is infeasible.
    let mut guard = 0usize;
    while !is_graphical(&e) {
        guard += 1;
        if guard > 4 * n * n + 16 {
            return Err(AllocationError::Invalid(
                "could not repair allocation to a graphical sequence".into(),
            ));
        }
        let imax = (0..n).max_by_key(|&i| e[i]).unwrap();
        let imin = (0..n)
            .filter(|&i| i != imax && e[i] < caps[i].min(n - 1))
            .min_by_key(|&i| e[i]);
        let Some(imin) = imin else {
            return Err(AllocationError::Invalid(
                "could not repair allocation to a graphical sequence".into(),
            ));
        };
        e[imax] -= 1;
        e[imin] += 1;
    }

    // Final unit bandwidth given the realized assignment.
    let b_unit = bw
        .iter()
        .zip(&e)
        .filter(|(_, &ei)| ei > 0)
        .map(|(&bi, &ei)| bi / ei as f64)
        .fold(f64::INFINITY, f64::min);

    Ok(AllocationResult {
        b_unit,
        edges_per_node: e,
    })
}

/// Erdős–Gallai test: is `deg` realizable as a simple graph?
pub fn is_graphical(deg: &[usize]) -> bool {
    let mut d: Vec<usize> = deg.to_vec();
    d.sort_unstable_by(|a, b| b.cmp(a));
    let total: usize = d.iter().sum();
    if total % 2 != 0 {
        return false;
    }
    let n = d.len();
    let mut lhs = 0usize;
    for k in 1..=n {
        lhs += d[k - 1];
        let mut rhs = k * (k - 1);
        for &di in &d[k..] {
            rhs += di.min(k);
        }
        if lhs > rhs {
            return false;
        }
    }
    true
}

/// Generalized Algorithm 1 over arbitrary physical **resources** (the paper:
/// "node *or link or port*; we use nodes for example"): each logical edge
/// consumes `multiplicity` resource slots (2 for node endpoints, 2 for BCube
/// ports — one per endpoint — and 1 for intra-server links, where an edge
/// maps to exactly its LCA link). Returns the per-resource edge capacities
/// that maximize the unit bandwidth while admitting `r` edges.
pub fn allocate_resource_capacity(
    bw: &[f64],
    r: usize,
    caps: &[usize],
    multiplicity: usize,
) -> Result<AllocationResult, AllocationError> {
    assert!(multiplicity >= 1);
    let n = bw.len();
    if n == 0 {
        return Err(AllocationError::Invalid("no resources".into()));
    }
    if caps.len() != n {
        return Err(AllocationError::Invalid("caps length mismatch".into()));
    }
    if bw.iter().any(|&b| !(b > 0.0)) {
        return Err(AllocationError::Invalid("bandwidths must be positive".into()));
    }
    let max_edges = caps.iter().sum::<usize>() / multiplicity;
    if r > max_edges {
        return Err(AllocationError::BudgetUnreachable { r, max: max_edges });
    }

    let assign = |b_unit: f64| -> Vec<usize> {
        bw.iter()
            .zip(caps)
            .map(|(&bi, &cap)| ((bi / b_unit * (1.0 + 1e-12)).floor() as usize).min(cap))
            .collect()
    };
    let count = |e: &[usize]| e.iter().sum::<usize>();
    let mut b_unit = bw.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut e = assign(b_unit);
    let mut guard = 0usize;
    while count(&e) < multiplicity * r {
        guard += 1;
        if guard > 10 * n * n + 1000 {
            return Err(AllocationError::Invalid("allocation failed to converge".into()));
        }
        let next = bw
            .iter()
            .zip(&e)
            .zip(caps)
            .filter(|((_, &ei), &cap)| ei < cap)
            .map(|((&bi, &ei), _)| bi / (ei + 1) as f64)
            .fold(f64::NEG_INFINITY, f64::max);
        if !next.is_finite() {
            e = caps.to_vec();
            break;
        }
        b_unit = next;
        e = assign(b_unit);
    }
    while count(&e) > multiplicity * r {
        let (imax, _) = e.iter().enumerate().max_by_key(|&(_, &ei)| ei).expect("nonempty");
        if e[imax] == 0 {
            break;
        }
        e[imax] -= 1;
    }
    let b_unit = bw
        .iter()
        .zip(&e)
        .filter(|(_, &ei)| ei > 0)
        .map(|(&bi, &ei)| bi / ei as f64)
        .fold(f64::INFINITY, f64::min);
    Ok(AllocationResult {
        b_unit,
        edges_per_node: e,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_allocation_is_uniform() {
        // 8 equal nodes, budget 8 edges → 2 edges each, b_unit = b/2.
        let bw = vec![9.76; 8];
        let caps = vec![7usize; 8];
        let a = allocate_edge_capacity(&bw, 8, &caps).unwrap();
        assert_eq!(a.edges_per_node.iter().sum::<usize>(), 16);
        assert_eq!(a.edges_per_node, vec![2; 8]);
        assert!((a.b_unit - 9.76 / 2.0).abs() < 1e-12);
    }

    #[test]
    fn paper_heterogeneous_setting() {
        // §VI-A2: n=16, ratios 3:…:3:1:…:1 (8 nodes at 9.76, 8 at 3.25).
        let mut bw = vec![9.76; 8];
        bw.extend(vec![3.25; 8]);
        let caps = vec![15usize; 16];
        for r in [16usize, 32, 48] {
            let a = allocate_edge_capacity(&bw, r, &caps).unwrap();
            assert_eq!(
                a.edges_per_node.iter().sum::<usize>(),
                2 * r,
                "r={r}: {:?}",
                a.edges_per_node
            );
            // Fast nodes get at least as many edges as slow ones.
            let min_fast = a.edges_per_node[..8].iter().min().unwrap();
            let max_slow = a.edges_per_node[8..].iter().max().unwrap();
            assert!(min_fast >= max_slow, "r={r}: {:?}", a.edges_per_node);
            // Every edge sees at least b_unit.
            for i in 0..16 {
                if a.edges_per_node[i] > 0 {
                    assert!(bw[i] / a.edges_per_node[i] as f64 >= a.b_unit - 1e-12);
                }
            }
        }
    }

    #[test]
    fn ratio_3_to_1_r16_gives_3x_edges() {
        // With bandwidth ratio 3:1 and loose budget, fast nodes should carry
        // ~3x the edges of slow nodes, keeping b_unit at the slow bandwidth.
        let mut bw = vec![9.76; 8];
        bw.extend(vec![3.25; 8]);
        let caps = vec![15usize; 16];
        let a = allocate_edge_capacity(&bw, 16, &caps).unwrap();
        // Initial assignment: floor(9.76/3.25)=3 edges for fast, 1 for slow
        // → 16 edges exactly = r. b_unit stays 3.25… with later exact split.
        assert!(a.b_unit >= 3.25 - 1e-9, "b_unit {}", a.b_unit);
        assert_eq!(a.edges_per_node[..8], [3, 3, 3, 3, 3, 3, 3, 3]);
        assert_eq!(a.edges_per_node[8..], [1, 1, 1, 1, 1, 1, 1, 1]);
    }

    #[test]
    fn caps_bind() {
        let bw = vec![10.0, 10.0, 1.0, 1.0];
        let caps = vec![2usize, 2, 2, 2];
        let a = allocate_edge_capacity(&bw, 4, &caps).unwrap();
        assert!(a.edges_per_node.iter().zip(&caps).all(|(e, c)| e <= c));
        assert_eq!(a.edges_per_node.iter().sum::<usize>(), 8);
    }

    #[test]
    fn unreachable_budget_errors() {
        let bw = vec![1.0; 4];
        let caps = vec![1usize; 4];
        let err = allocate_edge_capacity(&bw, 5, &caps).unwrap_err();
        assert!(matches!(err, AllocationError::BudgetUnreachable { .. }));
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(allocate_edge_capacity(&[1.0], 1, &[1]).is_err());
        assert!(allocate_edge_capacity(&[1.0, -1.0], 1, &[1, 1]).is_err());
        assert!(allocate_edge_capacity(&[1.0, 1.0], 1, &[1]).is_err());
    }

    #[test]
    fn graphicality_check_and_repair() {
        assert!(is_graphical(&[2, 2, 2]));
        assert!(is_graphical(&[3, 3, 3, 3]));
        assert!(!is_graphical(&[5, 5, 5, 5, 1, 1, 1, 1]));
        assert!(!is_graphical(&[1, 1, 1])); // odd sum
        // The degradation case that used to emit a non-graphical sequence:
        // 4 fast nodes at 9.76, 4 slow at 1.6, r = 12.
        let bw = [9.76, 9.76, 9.76, 9.76, 1.6, 1.6, 1.6, 1.6];
        let caps = [7usize; 8];
        let a = allocate_edge_capacity(&bw, 12, &caps).unwrap();
        assert!(is_graphical(&a.edges_per_node), "{:?}", a.edges_per_node);
        assert_eq!(a.edges_per_node.iter().sum::<usize>(), 24);
    }

    #[test]
    fn intra_server_link_allocation_paper_case() {
        // Fig. 3 server: links (PIX×4 at 4.88, NODE×2 at 4.88, SYS at 9.76),
        // hardware caps (1,1,1,1,4,4,16), multiplicity 1 (edge → LCA link).
        let bw = [4.88, 4.88, 4.88, 4.88, 4.88, 4.88, 9.76];
        let caps = [1usize, 1, 1, 1, 4, 4, 16];
        // r=8 → paper's b=1 case: every edge at the full 4.88 unit.
        let a = allocate_resource_capacity(&bw, 8, &caps, 1).unwrap();
        assert_eq!(a.edges_per_node.iter().sum::<usize>(), 8);
        assert!((a.b_unit - 4.88).abs() < 1e-9, "b_unit {}", a.b_unit);
        assert_eq!(a.edges_per_node, vec![1, 1, 1, 1, 1, 1, 2]);
        // r=12 → b=0.5 case.
        let a = allocate_resource_capacity(&bw, 12, &caps, 1).unwrap();
        assert_eq!(a.edges_per_node.iter().sum::<usize>(), 12);
        assert!((a.b_unit - 2.44).abs() < 1e-9, "b_unit {}", a.b_unit);
    }

    #[test]
    fn bcube_port_allocation_paper_case() {
        // BCube(4,2): 16 L0 ports at 4.88, 16 L1 ports at 9.76, cap p−1 = 3,
        // multiplicity 2 (an edge occupies a port at each endpoint).
        let mut bw = vec![4.88; 16];
        bw.extend(vec![9.76; 16]);
        let caps = vec![3usize; 32];
        let a = allocate_resource_capacity(&bw, 24, &caps, 2).unwrap();
        assert_eq!(a.edges_per_node.iter().sum::<usize>(), 48);
        assert!((a.b_unit - 4.88).abs() < 1e-9, "b_unit {}", a.b_unit);
        assert_eq!(&a.edges_per_node[..16], &vec![1; 16][..]);
        assert_eq!(&a.edges_per_node[16..], &vec![2; 16][..]);
    }

    #[test]
    fn b_unit_maximality_small_cases() {
        // Brute-force check on a small instance: no other integer assignment
        // with the same budget beats the returned b_unit.
        let bw = [4.0, 2.0, 1.0];
        let caps = [2usize, 2, 2];
        let r = 3usize;
        let got = allocate_edge_capacity(&bw, r, &caps).unwrap();
        let mut best = 0.0f64;
        for e0 in 0..=2usize {
            for e1 in 0..=2usize {
                for e2 in 0..=2usize {
                    if e0 + e1 + e2 != 2 * r {
                        continue;
                    }
                    let bu = [(0, e0), (1, e1), (2, e2)]
                        .iter()
                        .filter(|(_, e)| *e > 0)
                        .map(|&(i, e)| bw[i] / e as f64)
                        .fold(f64::INFINITY, f64::min);
                    best = best.max(bu);
                }
            }
        }
        assert!(
            got.b_unit >= best - 1e-9,
            "allocator b_unit {} < brute-force best {best}",
            got.b_unit
        );
    }
}
