//! # BA-Topo: Bandwidth-Aware Network Topology Optimization for Decentralized Learning
//!
//! Full-system reproduction of *"Bandwidth-Aware Network Topology Optimization
//! for Decentralized Learning"* (Shen et al., CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the ADMM-based Mixed-Integer-SDP topology optimizer
//!   ([`optimizer`]), the bandwidth-aware edge-capacity allocator and the four
//!   bandwidth scenario models ([`bandwidth`]), all baseline topologies
//!   ([`topo`]), a decentralized-learning coordinator with a simulated
//!   cluster clock ([`coordinator`], [`consensus`], [`training`]), and an
//!   online topology-optimization daemon with streaming telemetry ingest and
//!   pub/sub topology updates ([`serve`]).
//! - **L2/L1 (build-time Python, `python/compile/`)** — the transformer train
//!   step and the Pallas mixing / fused-SGD kernels, AOT-lowered to HLO text
//!   and executed from Rust through [`runtime`] (PJRT CPU via the `xla`
//!   crate). The same train/eval step also exists as a pure-Rust
//!   **host-native backend** ([`runtime::hostmodel`]), selected automatically
//!   by [`runtime::ExecBackend::auto`] when no artifacts are present — so
//!   every experiment family, including DSGD time-to-accuracy, runs offline.
//!
//! Python never runs at request time: after `make artifacts` the binary is
//! self-contained (and without artifacts it is self-contained from the start).
//!
//! The crate also carries its own reliability tooling: [`analysis`] is a
//! zero-dependency static-analysis pass (`batopo analyze`) that lints the
//! source tree for codebase-specific hazards — panics on runtime paths,
//! inconsistent lock orders, dropped thread handles, exact float compares —
//! behind a committed ratchet baseline in CI.

#![warn(missing_docs)]
// Numerical kernels here are written index-first on purpose (they mirror the
// paper's subscripted formulas); keep clippy's iterator-style nudges quiet.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity
)]

pub mod analysis;
pub mod bandwidth;
pub mod bench;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod graph;
pub mod linalg;
pub mod optimizer;
pub mod runtime;
pub mod serve;
pub mod topo;
pub mod training;
pub mod util;

/// Convenience re-exports of the most common public types.
///
/// The 30-second tour — build a baseline topology, run a short consensus
/// experiment under the paper's homogeneous bandwidth model, and check that
/// the error contracts:
///
/// ```
/// use batopo::prelude::*;
///
/// // A 8-node ring with Metropolis weights…
/// let topo: Topology = Baseline::Ring.build(8, 42);
/// assert_eq!(topo.num_nodes(), 8);
///
/// // …gossiping under 9.76 GB/s per-node bandwidth (Eq. 34 time model).
/// let scenario = BandwidthScenario::paper_homogeneous(8);
/// let cfg = ConsensusConfig { max_rounds: 200, ..Default::default() };
/// let run = run_consensus(None, &topo, &scenario, &TimeModel::default(), &cfg).unwrap();
///
/// assert!(run.trajectory.last().unwrap().error < run.trajectory[0].error);
/// assert!(run.iter_time > 0.0);
/// ```
pub mod prelude {
    pub use crate::bandwidth::scenario_dsl::{CompiledScenario, ScenarioBuilder};
    pub use crate::bandwidth::scenarios::BandwidthScenario;
    pub use crate::bandwidth::timing::TimeModel;
    pub use crate::consensus::{run_consensus, ConsensusConfig};
    pub use crate::graph::{Graph, Topology};
    pub use crate::optimizer::{BaTopoOptimizer, OptimizeSpec};
    pub use crate::topo::baselines::Baseline;
}
