//! # BA-Topo: Bandwidth-Aware Network Topology Optimization for Decentralized Learning
//!
//! Full-system reproduction of *"Bandwidth-Aware Network Topology Optimization
//! for Decentralized Learning"* (Shen et al., CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the ADMM-based Mixed-Integer-SDP topology optimizer
//!   ([`optimizer`]), the bandwidth-aware edge-capacity allocator and the four
//!   bandwidth scenario models ([`bandwidth`]), all baseline topologies
//!   ([`topo`]), and a decentralized-learning coordinator with a simulated
//!   cluster clock ([`coordinator`], [`consensus`], [`training`]).
//! - **L2/L1 (build-time Python, `python/compile/`)** — the transformer train
//!   step and the Pallas mixing / fused-SGD kernels, AOT-lowered to HLO text
//!   and executed from Rust through [`runtime`] (PJRT CPU via the `xla` crate).
//!
//! Python never runs at request time: after `make artifacts` the binary is
//! self-contained.

pub mod bandwidth;
pub mod bench;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod graph;
pub mod linalg;
pub mod optimizer;
pub mod runtime;
pub mod topo;
pub mod training;
pub mod util;

/// Convenience re-exports of the most common public types.
pub mod prelude {
    pub use crate::graph::{Graph, Topology};
    pub use crate::topo::baselines::Baseline;
}
