//! Integration tests for `batopo analyze`: per-rule fixtures through the
//! `analyze_sources` seam, suppression comments, the baseline ratchet, a scan
//! of the real tree pinned to the committed zero-findings guarantee for
//! `serve/` and `coordinator/`, and the CLI end to end.

use batopo::analysis::{analyze_root, analyze_sources, baseline, AnalysisOptions};
use std::path::Path;

fn srcs(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect()
}

#[test]
fn panic_rule_fires_on_runtime_paths_and_nowhere_else() {
    let src = "fn tick(v: Option<u8>) -> u8 { v.unwrap() }\n\
               fn boom() { panic!(\"down\"); }\n";
    let report = analyze_sources(&srcs(&[("serve/daemon.rs", src)]), None);
    assert_eq!(report.findings.len(), 2);
    assert!(report.findings.iter().all(|d| d.rule == "panic-in-runtime"));
    assert_eq!((report.findings[0].line, report.findings[1].line), (1, 2));
    // The same source outside the runtime prefixes is not lint-worthy.
    let report = analyze_sources(&srcs(&[("util/json.rs", src)]), None);
    assert!(report.findings.is_empty());
}

#[test]
fn float_eq_rule_fires_in_numeric_kernels_only() {
    let src = "fn z(x: f64, n: usize) -> bool { x == 0.0 || n == 7 }\n";
    let report = analyze_sources(&srcs(&[("linalg/dense.rs", src)]), None);
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "float-eq");
    assert!(analyze_sources(&srcs(&[("serve/daemon.rs", src)]), None).findings.is_empty());
}

#[test]
fn spawn_rule_flags_dropped_handles_but_not_bound_ones() {
    let dropped = "fn go() { std::thread::spawn(|| ()); }\n";
    let report = analyze_sources(&srcs(&[("telemetry/ingest.rs", dropped)]), None);
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "spawn-without-join");
    let bound = "fn go() { let h = std::thread::spawn(|| ()); h.join().ok(); }\n";
    assert!(analyze_sources(&srcs(&[("telemetry/ingest.rs", bound)]), None).findings.is_empty());
}

#[test]
fn two_functions_taking_locks_in_opposite_orders_are_a_cycle() {
    let src = "fn a(s: &S) { let _x = s.alpha.lock(); let _y = s.beta.lock(); }\n\
               fn b(s: &S) { let _y = s.beta.lock(); let _x = s.alpha.lock(); }\n";
    let report = analyze_sources(&srcs(&[("serve/state.rs", src)]), None);
    assert_eq!(report.findings.len(), 1);
    let d = &report.findings[0];
    assert_eq!(d.rule, "lock-order");
    assert!(d.message.contains("s.alpha") && d.message.contains("s.beta"), "{}", d.message);
    // Consistent order across the same two functions is clean.
    let src = "fn a(s: &S) { let _x = s.alpha.lock(); let _y = s.beta.lock(); }\n\
               fn b(s: &S) { let _x = s.alpha.lock(); let _y = s.beta.lock(); }\n";
    assert!(analyze_sources(&srcs(&[("serve/state.rs", src)]), None).findings.is_empty());
}

#[test]
fn allow_comment_suppresses_the_next_line_finding() {
    let src = "fn go() {\n\
               \x20   // batopo-allow: spawn-without-join\n\
               \x20   std::thread::spawn(|| ());\n\
               }\n";
    let report = analyze_sources(&srcs(&[("serve/daemon.rs", src)]), None);
    assert!(report.findings.is_empty());
    assert_eq!(report.suppressed, 1);
}

#[test]
fn rule_filter_restricts_the_run_to_one_rule() {
    let src = "fn f(v: Option<f64>) -> bool { v.unwrap() == 0.5 }\n";
    let report = analyze_sources(&srcs(&[("optimizer/admm.rs", src)]), Some("float-eq"));
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "float-eq");
}

#[test]
fn ratchet_fails_new_findings_and_reports_improvements() {
    let one = analyze_sources(
        &srcs(&[("serve/a.rs", "fn f(v: Option<u8>) -> u8 { v.unwrap() }\n")]),
        None,
    );
    let base = baseline::Baseline::from_findings(&one.findings);
    // A second panic site in the same file breaches the baseline.
    let two = analyze_sources(
        &srcs(&[("serve/a.rs", "fn f(v: Option<u8>) -> u8 { v.unwrap() + v.unwrap() }\n")]),
        None,
    );
    let out = baseline::ratchet(&base, &two.findings);
    assert_eq!(out.breaches.len(), 1);
    assert_eq!((out.breaches[0].baseline, out.breaches[0].current), (1, 2));
    // Fixing the finding is an improvement, never a failure.
    let fixed = analyze_sources(&srcs(&[("serve/a.rs", "fn f(v: u8) -> u8 { v }\n")]), None);
    let out = baseline::ratchet(&base, &fixed.findings);
    assert!(out.breaches.is_empty());
    assert_eq!(out.improvements.len(), 1);
    assert_eq!((out.improvements[0].baseline, out.improvements[0].current), (1, 0));
}

#[test]
fn real_tree_is_panic_free_on_serve_and_coordinator_and_matches_the_baseline() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let opts = AnalysisOptions { root: manifest.join("rust/src"), rule: None };
    let report = analyze_root(&opts).expect("scan rust/src");
    // The daemon and coordinator must stay free of panic paths, dropped
    // thread handles, and lock-order cycles — the whole point of the lint.
    let runtime_hits: Vec<String> = report
        .findings
        .iter()
        .filter(|d| {
            d.file.starts_with("serve/")
                || d.file.starts_with("coordinator/")
                || d.rule == "lock-order"
        })
        .map(ToString::to_string)
        .collect();
    assert!(runtime_hits.is_empty(), "runtime findings: {runtime_hits:#?}");
    let base =
        baseline::Baseline::load(&manifest.join("analysis/baseline.json")).expect("baseline");
    let out = baseline::ratchet(&base, &report.findings);
    assert!(out.breaches.is_empty(), "tree exceeds committed baseline: {:#?}", out.breaches);
}

#[test]
fn cli_analyze_is_clean_against_the_committed_baseline() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_batopo"))
        .current_dir(manifest)
        .args(["analyze", "--format", "json", "--baseline", "analysis/baseline.json"])
        .output()
        .expect("run batopo analyze");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(out.status.success(), "analyze must pass on the committed tree: {text}");
    assert!(text.contains("\"findings\""), "json findings array: {text}");
    assert!(text.contains("\"ratchet\""), "ratchet summary merged into the doc: {text}");
}

#[test]
fn cli_ratchet_breaches_fail_and_write_baseline_resets_the_gate() {
    let dir = std::env::temp_dir().join(format!("batopo-analyze-test-{}", std::process::id()));
    let root = dir.join("src");
    std::fs::create_dir_all(root.join("serve")).expect("create fixture tree");
    std::fs::write(root.join("serve/daemon.rs"), "fn f(v: Option<u8>) -> u8 { v.unwrap() }\n")
        .expect("write fixture");
    let empty = dir.join("empty.json");
    std::fs::write(&empty, "{\"schema_version\": 1, \"entries\": []}\n").expect("write baseline");
    let bin = env!("CARGO_BIN_EXE_batopo");
    let run = |args: &[&str]| {
        let out = std::process::Command::new(bin).args(args).output().expect("run batopo");
        let text = format!(
            "{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        (out.status.success(), text)
    };
    let root_s = root.to_str().expect("utf-8 path");
    let empty_s = empty.to_str().expect("utf-8 path");

    // A finding over an empty baseline fails the gate.
    let (ok, text) = run(&["analyze", "--root", root_s, "--baseline", empty_s]);
    assert!(!ok, "new finding must fail the ratchet: {text}");
    assert!(text.contains("exceed the analysis baseline"), "{text}");

    // `--write-baseline` records the current findings...
    let written = dir.join("baseline.json");
    let written_s = written.to_str().expect("utf-8 path");
    let (ok, text) =
        run(&["analyze", "--root", root_s, "--baseline", written_s, "--write-baseline"]);
    assert!(ok, "write-baseline must succeed: {text}");

    // ...after which the same tree gates clean.
    let (ok, text) = run(&["analyze", "--root", root_s, "--baseline", written_s]);
    assert!(ok, "refreshed baseline must gate clean: {text}");
    assert!(text.contains("clean against baseline"), "{text}");

    let _ = std::fs::remove_dir_all(&dir);
}
