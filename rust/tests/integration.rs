//! Cross-module integration & property tests (the `proptest`-style suite —
//! built on `batopo::util::prop` since the offline crate set has no
//! proptest). Each property states a system invariant the paper depends on.

use batopo::bandwidth::allocation::allocate_edge_capacity;
use batopo::bandwidth::scenarios::BandwidthScenario;
use batopo::bandwidth::timing::TimeModel;
use batopo::config;
use batopo::consensus::{run_consensus, ConsensusConfig};
use batopo::graph::laplacian::weight_matrix_from_edge_weights;
use batopo::graph::spectral::asymptotic_convergence_factor;
use batopo::graph::{incidence, Graph, Topology};
use batopo::linalg::{bicgstab, BicgstabOptions, CscMatrix, DenseMatrix, Ilu0, SymEigen};
use batopo::optimizer::{BaTopoOptimizer, OptimizeSpec};
use batopo::runtime::mixer::{MixVariant, Mixer};
use batopo::topo::{baselines, weights};
use batopo::util::prop::Runner;

// ---------------------------------------------------------------------------
// Spectral / gossip invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_connected_metropolis_topologies_contract() {
    Runner::new("connected + metropolis ⇒ r_asym < 1, W doubly stochastic", 40).run(|g| {
        let n = g.usize_in(3..24);
        let edges = g.connected_edges(n, 0.25);
        let graph = Graph::new(n, edges);
        let w = weight_matrix_from_edge_weights(&graph, &weights::metropolis(&graph));
        // Doubly stochastic + symmetric.
        for i in 0..n {
            let row: f64 = w.row(i).iter().sum();
            assert!((row - 1.0).abs() < 1e-9, "row {i} sums {row}");
        }
        assert!(w.is_symmetric(1e-12));
        // Non-negative entries (metropolis guarantee).
        assert!(w.data().iter().all(|&v| v >= -1e-12));
        // Contraction.
        let r = asymptotic_convergence_factor(&w);
        assert!(r < 1.0 - 1e-9, "r={r} for connected graph");
    });
}

#[test]
fn prop_weight_refinement_never_hurts() {
    Runner::new("optimize_weights ≤ metropolis r_asym", 15).run(|g| {
        let n = g.usize_in(4..12);
        let graph = Graph::new(n, g.connected_edges(n, 0.3));
        let base = weights::metropolis(&graph);
        let r0 = asymptotic_convergence_factor(&weight_matrix_from_edge_weights(&graph, &base));
        let opt = weights::optimize_weights(&graph, Some(&base), 80);
        let r1 = asymptotic_convergence_factor(&weight_matrix_from_edge_weights(&graph, &opt));
        assert!(r1 <= r0 + 1e-9, "refined {r1} > base {r0}");
        // Feasibility: g ≥ 0 and non-negative self-weights.
        assert!(opt.iter().all(|&x| x >= 0.0));
        let w = weight_matrix_from_edge_weights(&graph, &opt);
        for i in 0..n {
            assert!(w[(i, i)] >= -1e-9);
        }
    });
}

#[test]
fn prop_consensus_error_tracks_spectral_rate() {
    Runner::new("empirical contraction ≈ r_asym", 8).run(|g| {
        let n = g.usize_in(4..14);
        let graph = Graph::new(n, g.connected_edges(n, 0.4));
        let w = weight_matrix_from_edge_weights(&graph, &weights::metropolis(&graph));
        let topo = Topology::new(graph, w, "prop");
        let sc = BandwidthScenario::paper_homogeneous(n);
        let run = run_consensus(
            None,
            &topo,
            &sc,
            &TimeModel::default(),
            &ConsensusConfig {
                eps: 1e-5,
                max_rounds: 4000,
                seed: 1 + g.case as u64,
                ..Default::default()
            },
        )
        .unwrap();
        let spectral = topo.asymptotic_convergence_factor();
        // Empirical rate must not beat the spectral bound by a wide margin
        // and should be in its vicinity once converged.
        if run.convergence_rounds.is_some() {
            assert!(
                run.empirical_rate <= spectral + 0.08,
                "empirical {} vs spectral {spectral}",
                run.empirical_rate
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Bandwidth invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_allocation_invariants() {
    Runner::new("Algorithm 1 invariants", 60).run(|g| {
        let n = g.usize_in(2..20);
        let bw: Vec<f64> = (0..n).map(|_| g.f64_in(0.5..20.0)).collect();
        let caps = vec![n - 1; n];
        let max_r = n * (n - 1) / 2;
        let r = g.usize_in(1..max_r.max(2));
        match allocate_edge_capacity(&bw, r, &caps) {
            Ok(a) => {
                // Exact endpoint budget.
                assert_eq!(a.edges_per_node.iter().sum::<usize>(), 2 * r);
                // Caps respected.
                assert!(a.edges_per_node.iter().all(|&e| e <= n - 1));
                // Every loaded node meets the unit bandwidth.
                for (b, &e) in bw.iter().zip(&a.edges_per_node) {
                    if e > 0 {
                        assert!(b / e as f64 >= a.b_unit - 1e-9);
                    }
                }
                // Unit bandwidth no better than the single-edge optimum.
                assert!(a.b_unit <= bw.iter().cloned().fold(0.0, f64::max) + 1e-9);
            }
            Err(_) => {
                // Only permissible when the caps genuinely cannot host r edges.
                assert!(2 * r > n * (n - 1), "allocation refused feasible budget");
            }
        }
    });
}

#[test]
fn prop_edge_bandwidths_positive_and_bounded() {
    Runner::new("per-edge bandwidths ∈ (0, node max]", 30).run(|g| {
        let n = 16;
        let graph = Graph::new(n, g.connected_edges(n, 0.2));
        let w = weight_matrix_from_edge_weights(&graph, &weights::metropolis(&graph));
        let topo = Topology::new(graph, w, "prop");
        for sc in [
            BandwidthScenario::paper_homogeneous(n),
            BandwidthScenario::paper_node_level(),
            BandwidthScenario::paper_inter_server(),
        ] {
            let bws = sc.edge_bandwidths(&topo);
            assert_eq!(bws.len(), topo.num_edges());
            assert!(bws.iter().all(|&b| b > 0.0 && b <= 9.76 + 1e-9), "{bws:?}");
            let tm = TimeModel::default();
            let t_iter = tm
                .consensus_iter_time(&sc, &topo)
                .expect("positive-bandwidth scenarios have finite round times");
            assert!(t_iter >= tm.t_comm - 1e-12);
        }
    });
}

// ---------------------------------------------------------------------------
// Linear algebra invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_csc_matches_dense() {
    Runner::new("CSC matvec/transpose == dense", 40).run(|g| {
        let rows = g.usize_in(1..20);
        let cols = g.usize_in(1..20);
        let mut trips = Vec::new();
        let nnz = g.usize_in(0..rows * cols + 1);
        for _ in 0..nnz {
            trips.push((g.usize_in(0..rows), g.usize_in(0..cols), g.f64_in(-2.0..2.0)));
        }
        let a = CscMatrix::from_triplets(rows, cols, trips);
        let d = a.to_dense();
        let x: Vec<f64> = (0..cols).map(|_| g.gaussian()).collect();
        let y: Vec<f64> = (0..rows).map(|_| g.gaussian()).collect();
        let ax = a.matvec(&x);
        let dx = d.matvec(&x);
        for (p, q) in ax.iter().zip(&dx) {
            assert!((p - q).abs() < 1e-10);
        }
        let aty = a.matvec_transpose(&y);
        let dty = d.transpose().matvec(&y);
        for (p, q) in aty.iter().zip(&dty) {
            assert!((p - q).abs() < 1e-10);
        }
    });
}

#[test]
fn prop_bicgstab_solves_diag_dominant() {
    Runner::new("BiCGSTAB + ILU solves diagonally dominant systems", 20).run(|g| {
        let n = g.usize_in(5..60);
        let mut trips = Vec::new();
        let mut row_mass = vec![0.0f64; n];
        for i in 0..n {
            for _ in 0..3 {
                let j = g.usize_in(0..n);
                if j != i {
                    let v = g.f64_in(-1.0..1.0);
                    trips.push((i, j, v));
                    row_mass[i] += v.abs();
                }
            }
        }
        for i in 0..n {
            trips.push((i, i, row_mass[i] + 1.0 + g.f64_in(0.0..1.0)));
        }
        let a = CscMatrix::from_triplets(n, n, trips);
        let b: Vec<f64> = (0..n).map(|_| g.gaussian()).collect();
        let ilu = Ilu0::factor(&a, 1e-10);
        let (x, out) = bicgstab(&a, &b, Some(&ilu), &BicgstabOptions::default());
        assert!(out.converged, "{out:?}");
        let r: Vec<f64> = a.matvec(&x).iter().zip(&b).map(|(p, q)| p - q).collect();
        let rn = r.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(rn < 1e-6, "residual {rn}");
    });
}

#[test]
fn prop_eigen_reconstruction_and_bounds() {
    Runner::new("Jacobi eigendecomposition reconstructs + bounds spectrum", 25).run(|g| {
        let n = g.usize_in(2..16);
        let data = g.sym_matrix(n, -3.0..3.0);
        let a = DenseMatrix::from_vec(n, n, data);
        let e = SymEigen::new(&a);
        let recon = e.apply_spectral(|l| l);
        assert!(a.max_abs_diff(&recon) < 1e-8 * (1.0 + a.frob()));
        // Rayleigh bound: x^T A x ≤ λ_max ‖x‖².
        let x: Vec<f64> = (0..n).map(|_| g.gaussian()).collect();
        let ax = a.matvec(&x);
        let xtax: f64 = x.iter().zip(&ax).map(|(p, q)| p * q).sum();
        let xx: f64 = x.iter().map(|v| v * v).sum();
        assert!(xtax <= e.max() * xx + 1e-8 * (1.0 + xtax.abs()));
        assert!(xtax >= e.min() * xx - 1e-8 * (1.0 + xtax.abs()));
    });
}

// ---------------------------------------------------------------------------
// Edge-space / serialization invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_edge_index_bijection() {
    Runner::new("edge_index ∘ edge_pair = id", 20).run(|g| {
        let n = g.usize_in(2..40);
        for l in 0..incidence::num_possible_edges(n) {
            let (i, j) = incidence::edge_pair(n, l);
            assert_eq!(incidence::edge_index(n, i, j), l);
        }
    });
}

#[test]
fn prop_topology_json_roundtrip() {
    Runner::new("topology JSON roundtrip preserves spectra", 20).run(|g| {
        let n = g.usize_in(3..16);
        let graph = Graph::new(n, g.connected_edges(n, 0.3));
        let w = weight_matrix_from_edge_weights(&graph, &weights::metropolis(&graph));
        let topo = Topology::new(graph, w, format!("prop-{}", g.case));
        let j = config::topology_to_json(&topo);
        let back = config::topology_from_json(&j).unwrap();
        assert_eq!(back.graph.edges(), topo.graph.edges());
        assert!(
            (back.asymptotic_convergence_factor() - topo.asymptotic_convergence_factor()).abs()
                < 1e-9
        );
    });
}

// ---------------------------------------------------------------------------
// Optimizer end-to-end invariants
// ---------------------------------------------------------------------------

#[test]
fn optimizer_beats_every_baseline_weight_rule_on_its_own_support() {
    // Hand the optimizer the torus's edge budget: it must produce something
    // at least as good as the metropolis-weighted torus.
    let n = 16;
    let torus = baselines::torus2d(n);
    let mut spec = OptimizeSpec::homogeneous(n, torus.num_edges());
    spec.max_iters = 100;
    spec.anneal_steps = 800;
    spec.polish_swaps = 30;
    spec.refine_iters = 200;
    spec.restarts = 2;
    let rep = BaTopoOptimizer::new(spec).run_detailed().unwrap();
    assert!(
        rep.r_asym <= torus.asymptotic_convergence_factor() + 1e-6,
        "BA {} vs torus {}",
        rep.r_asym,
        torus.asymptotic_convergence_factor()
    );
    assert!(rep.constraint_check.is_ok());
}

#[test]
fn optimizer_heterogeneous_tree_respects_link_allocation() {
    let sc = BandwidthScenario::paper_intra_server();
    let mut spec = OptimizeSpec::with_scenario(sc.clone(), 8);
    spec.max_iters = 60;
    spec.anneal_steps = 300;
    spec.polish_swaps = 10;
    spec.refine_iters = 100;
    let topo = BaTopoOptimizer::new(spec).run().unwrap();
    // Full unit bandwidth: the allocation caps force ≤1 edge per PIX/NODE
    // link and ≤2 on SYS at r=8.
    let b_min = sc.min_edge_bandwidth(&topo);
    assert!((b_min - 4.88).abs() < 1e-9, "b_min {b_min}");
    assert_eq!(topo.num_edges(), 8);
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn mixer_rejects_ragged_state() {
    let topo = baselines::ring(4);
    let mixer = Mixer::new(None, &topo, MixVariant::HostFallback).unwrap();
    let ragged = vec![vec![0.0f32; 4], vec![0.0f32; 5], vec![0.0; 4], vec![0.0; 4]];
    assert!(
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| mixer.mix(&ragged))).is_err()
    );
}

#[test]
fn optimizer_rejects_impossible_budgets() {
    // Budget below spanning tree.
    assert!(BaTopoOptimizer::new(OptimizeSpec::homogeneous(8, 4)).run().is_err());
    // Budget above |E|.
    assert!(BaTopoOptimizer::new(OptimizeSpec::homogeneous(4, 10)).run().is_err());
    // BCube budget above eligible pairs.
    let sc = BandwidthScenario::paper_inter_server();
    let spec = OptimizeSpec::with_scenario(sc, 100);
    assert!(BaTopoOptimizer::new(spec).run().is_err());
}

#[test]
fn corrupt_topology_files_are_rejected() {
    let dir = std::env::temp_dir().join("batopo_integration_corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.json");
    std::fs::write(&path, "{\"n\": 4, \"edges\": [[0,1]]").unwrap(); // truncated
    assert!(config::load_topology(&path).is_err());
    std::fs::write(&path, "{\"n\": 4, \"edges\": [[0,9]], \"weights\": []}").unwrap();
    assert!(
        std::panic::catch_unwind(|| config::load_topology(&path)).is_err()
            || config::load_topology(&path).is_err()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_presets_validate_node_counts() {
    assert!(config::scenario_by_name("intra-server", 16).is_err());
    assert!(config::scenario_by_name("inter-server", 8).is_err());
    assert!(config::scenario_by_name("node-level", 7).is_err());
}
