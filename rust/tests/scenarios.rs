//! Integration tests for the adversarial scenario corpus, the scripted-event
//! semantics of `simulate_scripted_consensus`, the scenario fuzzer's
//! shrink-and-dump path, and the `reproduce dynamic` per-scenario markdown
//! analysis reports.

use batopo::bandwidth::corpus::{corpus, ScenarioProgram};
use batopo::bandwidth::dynamic::{simulate_scripted_consensus, BandwidthTrace, DynamicPolicy};
use batopo::bandwidth::fuzz::{
    check_program, fuzz_scenarios, replay, shrink_failing, FuzzConfig, Invariant,
};
use batopo::bandwidth::scenario_dsl::{
    CompiledScenario, ScenarioBuilder, ScenarioEvent, ScheduledEvent,
};
use batopo::bench::experiments::{self, ExpOptions};

// ---------------------------------------------------------------------------
// Corpus catalogue
// ---------------------------------------------------------------------------

#[test]
fn corpus_covers_the_required_scenarios_and_roundtrips() {
    let suite = corpus(8, true, 42);
    assert!(suite.len() >= 10, "corpus has only {} scenarios", suite.len());
    for want in [
        "heavy-tailed",
        "correlated",
        "partition-heal",
        "stragglers",
        "zonal-outage",
        "diurnal",
    ] {
        let entry = suite
            .iter()
            .find(|s| s.name == want)
            .unwrap_or_else(|| panic!("corpus is missing scenario {want}"));
        assert!(!entry.hypothesis.is_empty(), "{want} has no hypothesis");
        // Every entry is a replayable program: dump → parse is the identity,
        // and the reparsed program compiles to the identical trace.
        let reparsed = ScenarioProgram::parse(&entry.program.dump())
            .unwrap_or_else(|e| panic!("{want} dump does not parse: {e}"));
        assert_eq!(reparsed, entry.program, "{want} does not round-trip");
        let a = entry.program.compile();
        let b = reparsed.compile();
        assert_eq!(a.trace.phases, b.trace.phases, "{want} traces differ");
        assert!(!a.reports.is_empty(), "{want} has no checkpoints");
        assert!(a.trace.phases.iter().flatten().all(|&bw| bw > 0.0));
    }
}

// ---------------------------------------------------------------------------
// Scripted event semantics
// ---------------------------------------------------------------------------

#[test]
fn clamp_is_applied_after_drift_and_after_scripted_events() {
    // σ = 3.0 steps move bandwidths by e^±3 per phase: without the clamp the
    // values would leave [4, 6] almost surely, so staying inside proves the
    // clamp runs after every drift step.
    let s = ScenarioBuilder::new(vec![5.0; 4]).phases(8).clamp(4.0, 6.0).drift(3.0).compile(11);
    assert!(s
        .trace
        .phases
        .iter()
        .flatten()
        .all(|&b| (4.0..=6.0).contains(&b)));
    // Scripted values are clamped too: a set_bandwidth above the ceiling
    // lands exactly on it.
    let s = ScenarioBuilder::new(vec![5.0; 2])
        .phases(3)
        .clamp(4.0, 6.0)
        .at_phase(1)
        .set_bandwidth(0, 100.0)
        .build();
    assert_eq!(s.trace.phases[1][0], 6.0);
    assert_eq!(s.trace.phases[2][0], 6.0);
}

#[test]
fn churn_floor_is_honored_on_leave_and_rejoin() {
    let s = ScenarioBuilder::new(vec![9.76; 3])
        .phases(4)
        .churn_floor(0.5)
        .at_phase(1)
        .node_churn(2, None)
        .at_phase(2)
        .node_churn(2, Some(0.2)) // below the floor: lifted
        .at_phase(3)
        .node_churn(2, Some(2.0)) // above the floor: exact
        .build();
    assert_eq!(s.trace.phases[1][2], 0.5, "leave lands on the floor");
    assert_eq!(s.trace.phases[2][2], 0.5, "rejoin below the floor is lifted");
    assert_eq!(s.trace.phases[3][2], 2.0, "rejoin above the floor is exact");
}

#[test]
fn at_phase_events_are_applied_exactly_once() {
    // A ×0.5 degrade at phase 1 must not compound in later phases.
    let s = ScenarioBuilder::new(vec![9.76; 2])
        .phases(5)
        .at_phase(1)
        .link_degrade(&[0], 0.5)
        .build();
    assert_eq!(s.trace.phases[0][0], 9.76);
    assert_eq!(s.trace.phases[1][0], 4.88);
    assert_eq!(s.trace.phases[2][0], 4.88, "event re-applied at phase 2");
    assert_eq!(s.trace.phases[4][0], 4.88, "event re-applied later");
    assert_eq!(s.trace.phases[4][1], 9.76, "unlisted node touched");
}

#[test]
fn zero_bandwidth_outage_phase_pauses_gossip_without_panicking() {
    // Regression against TimeModel's TimingError: a phase with an exactly-zero
    // bandwidth (an outage) must elapse with no gossip rounds — not panic,
    // not produce non-finite report rows.
    let n = 6;
    let healthy = vec![9.76; n];
    let mut outage = healthy.clone();
    outage[0] = 0.0;
    let scenario = CompiledScenario {
        trace: BandwidthTrace {
            phases: vec![healthy.clone(), outage, healthy],
            phase_seconds: 0.5,
        },
        reports: vec![
            (0, "before".to_string()),
            (1, "during outage".to_string()),
            (2, "after".to_string()),
        ],
        events: Vec::new(),
    };
    let policy = DynamicPolicy {
        r: 8,
        quick: true,
        ..Default::default()
    };
    let run = simulate_scripted_consensus(&scenario, policy, false, 3);
    assert_eq!(run.reports.len(), 3);
    let (before, during, after) = (&run.reports[0], &run.reports[1], &run.reports[2]);
    assert!(before.rounds > 0, "healthy phase must gossip");
    assert_eq!(during.rounds, before.rounds, "outage phase executed rounds");
    assert!(after.rounds > during.rounds, "recovery phase must gossip");
    assert_eq!(during.b_min, 0.0, "outage b_min must be zero");
    assert!(run.reports.iter().all(|r| r.log_error.is_finite()));
    assert!(run.outcome.final_log_error.is_finite());
}

// ---------------------------------------------------------------------------
// Fuzzer: seeded known-bad invariant → shrunk, replayable dump
// ---------------------------------------------------------------------------

/// Fleet-wide partition at the churn floor: the round time (~2.9 s at
/// 0.05 GB/s) exceeds the 1.5 s phase, so partition-phase checkpoints see no
/// new gossip rounds — legal behavior (Core holds), but a violation of the
/// deliberately-too-strict `every-phase-gossips` invariant.
fn known_bad_program() -> ScenarioProgram {
    let n = 6;
    let mut events = vec![ScheduledEvent {
        phase: 1,
        event: ScenarioEvent::Partition {
            nodes: (0..n).collect(),
        },
    }];
    for k in 0..3 {
        events.push(ScheduledEvent {
            phase: k,
            event: ScenarioEvent::ReportStats {
                label: format!("phase {k}"),
            },
        });
    }
    ScenarioProgram {
        initial: vec![9.76; n],
        phases: 3,
        phase_seconds: 1.5,
        clamp: (1e-3, f64::INFINITY),
        churn_floor: 0.05,
        seed: 13,
        events,
    }
}

#[test]
fn known_bad_invariant_produces_a_smaller_replayable_dump() {
    let original = known_bad_program();
    assert!(check_program(&original, Invariant::Core).is_ok(), "core must hold on outages");
    assert!(
        check_program(&original, Invariant::EveryPhaseGossips).is_err(),
        "the known-bad invariant must fail"
    );

    let shrunk = shrink_failing(&original, Invariant::EveryPhaseGossips);
    assert!(
        shrunk.events.len() < original.events.len(),
        "shrunk dump must have fewer events: {} vs {}",
        shrunk.events.len(),
        original.events.len()
    );

    // The dump is replayable: written to disk, parsed back, still failing the
    // bad invariant while passing core.
    let dir = std::env::temp_dir().join("batopo_fuzz_dump_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("known_bad.scenario");
    std::fs::write(&path, shrunk.dump()).unwrap();
    let (reparsed, violation) = replay(&path, Invariant::EveryPhaseGossips).expect("replay");
    assert_eq!(reparsed, shrunk);
    assert!(violation.is_some(), "replayed dump no longer fails");
    let (_, core_violation) = replay(&path, Invariant::Core).expect("replay");
    assert!(core_violation.is_none(), "core must still hold on the dump");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fuzz_core_invariant_holds_on_random_programs() {
    let dir = std::env::temp_dir().join("batopo_fuzz_core_test");
    std::fs::remove_dir_all(&dir).ok();
    let cfg = FuzzConfig {
        cases: 4,
        quick: true,
        out_dir: dir.clone(),
        ..Default::default()
    };
    let outcome = fuzz_scenarios(&cfg).expect("fuzz run");
    assert_eq!(outcome.cases, 4);
    assert!(
        outcome.failures.is_empty(),
        "core invariant violated: {:?}",
        outcome.failures.iter().map(|f| &f.violation).collect::<Vec<_>>()
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// `reproduce dynamic --quick` — per-scenario analysis reports
// ---------------------------------------------------------------------------

#[test]
fn reproduce_dynamic_quick_writes_per_scenario_reports() {
    let dir = std::env::temp_dir().join("batopo_reproduce_dynamic_test");
    std::fs::remove_dir_all(&dir).ok();
    let opts = ExpOptions {
        quick: true,
        out_dir: dir.clone(),
        seed: 42,
        ..Default::default()
    };
    experiments::run(&["dynamic".to_string()], &opts);

    let csv = std::fs::read_to_string(dir.join("dynamic.csv")).expect("dynamic.csv");
    let header = csv.lines().next().expect("header");
    assert!(
        header.ends_with("final_log10_error,time_to_target_s"),
        "dynamic.csv lacks the time-to-target column: {header}"
    );
    assert!(csv.lines().count() > 1, "dynamic.csv has no data rows");

    let manifest =
        std::fs::read_to_string(dir.join("run_manifest.json")).expect("run_manifest.json");
    let required = [
        "scenario_heavy-tailed.md",
        "scenario_correlated.md",
        "scenario_partition-heal.md",
        "scenario_stragglers.md",
        "scenario_zonal-outage.md",
        "scenario_diurnal.md",
    ];
    for name in required {
        let md = std::fs::read_to_string(dir.join(name))
            .unwrap_or_else(|e| panic!("{name} not written: {e}"));
        for section in ["## Hypothesis", "## Configuration", "## Checkpoints"] {
            assert!(md.contains(section), "{name} missing {section}");
        }
        assert!(md.contains("## Finding"), "{name} missing the finding");
        assert!(
            manifest.contains(&format!("\"{name}\"")),
            "run_manifest.json does not reference {name}"
        );
    }
    let md_count = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let n = e.file_name().to_string_lossy().to_string();
            n.starts_with("scenario_") && n.ends_with(".md")
        })
        .count();
    assert!(md_count >= 6, "only {md_count} scenario reports written");
    std::fs::remove_dir_all(&dir).ok();
}
