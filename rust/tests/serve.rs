//! End-to-end tests for the `batopo serve` daemon: a streamed corpus
//! scenario over the real TCP wire protocol, incremental re-optimization,
//! pub/sub topology updates to multiple subscribers, clean shutdown — plus
//! the `fuzz replay` CLI exit-code contract.

use batopo::bandwidth::corpus::{corpus, ScenarioProgram};
use batopo::bandwidth::scenario_dsl::{ScenarioEvent, ScheduledEvent};
use batopo::serve::protocol::event_line;
use batopo::serve::sim::{run as sim_run, SimConfig};
use batopo::serve::{spawn, ServeConfig, TopologyUpdate};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// A blocking line-oriented test client with a generous read timeout.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .expect("set read timeout");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { stream, reader }
    }

    fn send(&mut self, line: &str) {
        self.stream
            .write_all(format!("{line}\n").as_bytes())
            .and_then(|()| self.stream.flush())
            .expect("send line");
    }

    /// Read one line; `None` on EOF.
    fn read_line(&mut self) -> Option<String> {
        let mut buf = String::new();
        match self.reader.read_line(&mut buf).expect("read line") {
            0 => None,
            _ => Some(buf.trim_end().to_string()),
        }
    }

    /// Send a command and return its single reply line.
    fn cmd(&mut self, line: &str) -> String {
        self.send(line);
        self.read_line().expect("reply before EOF")
    }

    /// Send a command and assert an `ok …` reply.
    fn ok(&mut self, line: &str) -> String {
        let reply = self.cmd(line);
        assert!(reply.starts_with("ok"), "expected ok for {line:?}, got {reply:?}");
        reply
    }

    /// Send a command and assert an `err …` reply.
    fn err(&mut self, line: &str) -> String {
        let reply = self.cmd(line);
        assert!(reply.starts_with("err"), "expected err for {line:?}, got {reply:?}");
        reply
    }

    /// Read one framed `update … end` block.
    fn read_update(&mut self) -> TopologyUpdate {
        let mut frame = String::new();
        loop {
            let line = self.read_line().expect("update frame before EOF");
            if frame.is_empty() {
                assert!(line.starts_with("update "), "expected update frame, got {line:?}");
            }
            frame.push_str(&line);
            frame.push('\n');
            if line.starts_with("end ") {
                return TopologyUpdate::from_wire(&frame).expect("parse update frame");
            }
        }
    }

    /// Collect update frames until the daemon closes the connection.
    fn drain_updates_to_eof(mut self) -> Vec<TopologyUpdate> {
        let mut updates = Vec::new();
        let mut frame = String::new();
        let mut in_frame = false;
        while let Some(line) = self.read_line() {
            if line.starts_with("update ") {
                in_frame = true;
                frame.clear();
            }
            if in_frame {
                frame.push_str(&line);
                frame.push('\n');
                if line.starts_with("end ") {
                    in_frame = false;
                    updates.push(TopologyUpdate::from_wire(&frame).expect("parse update frame"));
                }
            }
        }
        updates
    }
}

fn parse_stats(line: &str) -> HashMap<String, u64> {
    let mut toks = line.split_whitespace();
    assert_eq!(toks.next(), Some("stats"), "not a stats line: {line:?}");
    let mut m = HashMap::new();
    while let Some(k) = toks.next() {
        m.insert(k.to_string(), toks.next().expect("stats value").parse().expect("stats number"));
    }
    m
}

/// Poll `stats` until no solve is in flight; returns the final snapshot.
fn drain_inflight(driver: &mut Client) -> HashMap<String, u64> {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let m = parse_stats(&driver.cmd("stats"));
        if m["inflight"] == 0 {
            return m;
        }
        assert!(Instant::now() < deadline, "re-optimizations never drained");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn degrade_program() -> ScenarioProgram {
    corpus(8, true, 42)
        .into_iter()
        .find(|s| s.name == "degrade")
        .expect("corpus has a degrade scenario")
        .program
}

/// The acceptance smoke: a daemon ingests a streamed corpus scenario under a
/// fixed seed, triggers incumbent-warm-started re-optimizations on the
/// sparse candidate path, publishes versioned updates to two subscribers,
/// and shuts down cleanly.
#[test]
fn daemon_streams_degrade_and_publishes_to_two_subscribers() {
    let handle = spawn(ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        r: Some(8),
        hysteresis: 1.02,
        ..ServeConfig::default()
    })
    .expect("spawn daemon");
    let addr = handle.addr;

    // Subscribers first, so version 1 reaches both.
    let mut subs: Vec<Client> = (0..2)
        .map(|i| {
            let mut c = Client::connect(addr);
            c.ok(&format!("hello sub-{i}"));
            c.ok("subscribe");
            c
        })
        .collect();

    // Driver: stream the quick degrade scenario over the wire.
    let program = degrade_program();
    let mut driver = Client::connect(addr);
    driver.ok("hello driver");
    driver.ok(&format!("seed {}", program.seed));
    driver.ok(&format!("phase_seconds {}", program.phase_seconds));
    driver.ok(&format!("clamp {} {}", program.clamp.0, program.clamp.1));
    driver.ok(&format!("churn_floor {}", program.churn_floor));
    let init: Vec<String> = program.initial.iter().map(|b| b.to_string()).collect();
    let reply = driver.ok(&format!("init {}", init.join(" ")));
    assert!(reply.contains("n 8"), "init reply names the fleet: {reply:?}");
    assert!(reply.contains("candidates knn:6"), "init reply names the support: {reply:?}");
    for ev in &program.events {
        driver.ok(&event_line(ev.phase, &ev.event));
    }
    for epoch in 1..program.phases as u64 {
        let reply = driver.ok("tick");
        assert_eq!(reply, format!("ok tick {epoch}"));
    }

    let stats = drain_inflight(&mut driver);
    assert_eq!(stats["epochs"], program.phases as u64 - 1);
    assert!(stats["reopts"] >= 1, "no re-optimization completed: {stats:?}");
    assert!(stats["updates"] >= 1, "nothing published: {stats:?}");
    assert_eq!(stats["sessions"], 3);

    driver.ok("shutdown");
    assert!(driver.read_line().is_none(), "driver socket closes after shutdown");

    for (i, sub) in subs.drain(..).enumerate() {
        let updates = sub.drain_updates_to_eof();
        assert!(!updates.is_empty(), "subscriber {i} received no update");
        let first = &updates[0];
        assert_eq!(first.version, 1, "first update is the initial topology");
        assert_eq!(first.epoch, 0);
        assert!(!first.switched);
        for u in &updates {
            assert_eq!(u.n, 8);
            assert_eq!(u.edges.len(), 8, "budget r=8 respected in v{}", u.version);
            for &(a, b, w) in &u.edges {
                assert!(a < b && b < 8, "canonical in-range edge ({a},{b})");
                assert!(w.is_finite() && w > 0.0, "finite positive weight {w}");
            }
            assert!(u.r_asym.is_finite() && u.lambda2 > 0.0, "connected spectral stats");
        }
        let versions: Vec<u64> = updates.iter().map(|u| u.version).collect();
        assert!(versions.windows(2).all(|w| w[0] < w[1]), "versions increase: {versions:?}");
    }

    let final_stats = handle.join();
    assert!(final_stats.updates_published >= 1);
    assert!(final_stats.update_fanout >= 2, "both subscribers counted in fanout");
    assert!(final_stats.reopts >= 1);
    assert_eq!(final_stats.sessions_served, 3);
}

#[test]
fn daemon_enforces_protocol_order_and_rejects_bad_lines() {
    let handle = spawn(ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        ..ServeConfig::default()
    })
    .expect("spawn daemon");
    let mut c = Client::connect(handle.addr);

    // Before init: service verbs that need a fleet are rejected…
    c.err("tick");
    c.err("event 1 drift 0.1");
    // …as are malformed and invalid lines.
    c.err("frobnicate");
    c.err("clamp 5 1");
    c.err("phase_seconds nope");
    c.err("init 1 2 3"); // too few nodes
    c.err("init 1 2 3 -4"); // non-positive bandwidth

    c.ok("phase_seconds 2.0");
    c.ok("init 9.76 9.76 3.25 3.25 9.76 9.76");

    // After init: config is frozen, re-init is rejected, events validate.
    c.err("phase_seconds 3.0");
    c.err("seed 7");
    c.err("init 1 1 1 1");
    c.err("event 1 set_bandwidth 12 5.0"); // node out of range
    c.err("event 1 drift -0.5");
    c.ok("event 1 drift 0.1");

    // Subscribe after the initial solve: version 1 is replayed immediately.
    let mut sub = Client::connect(handle.addr);
    drain_inflight(&mut c);
    sub.ok("subscribe");
    let replayed = sub.read_update();
    assert_eq!(replayed.version, 1);
    assert_eq!(replayed.n, 6);
    assert!(!replayed.switched);

    c.ok("quit");
    assert!(c.read_line().is_none(), "quit closes only this session");
    let mut d = Client::connect(handle.addr);
    d.ok("shutdown");
    handle.join();
}

#[test]
fn serve_sim_in_process_reports_updates_and_latencies() {
    let report = sim_run(&SimConfig::default()).expect("sim completes");
    assert_eq!(report.clients, 2);
    assert_eq!(report.updates_per_client.len(), 2);
    assert!(report.min_updates_per_client >= 1, "every subscriber got an update: {report:?}");
    assert_eq!(report.epochs, 3, "quick corpus horizon is 4 phases");
    assert!(report.reopts >= 1);
    assert!(report.published >= 1);
    assert!(report.fanout >= 2);
    assert!(!report.latencies_ms.is_empty());
    assert!(report.latencies_ms.iter().all(|&l| l >= 0.0));
    assert!(report.p95_latency_ms >= report.latencies_ms[0]);
    let rendered = report.render();
    assert!(rendered.contains("scenario=degrade"));
    assert!(rendered.contains("latency_ms"));
}

/// The fuzzer's known-bad program (full-fleet partition at the churn floor:
/// round time exceeds the phase, so `every-phase-gossips` fails while the
/// core invariants hold).
fn known_bad_dump() -> String {
    let n = 6;
    let mut events = vec![ScheduledEvent {
        phase: 1,
        event: ScenarioEvent::Partition {
            nodes: (0..n).collect(),
        },
    }];
    for phase in 0..3 {
        events.push(ScheduledEvent {
            phase,
            event: ScenarioEvent::ReportStats {
                label: format!("phase {phase}"),
            },
        });
    }
    let program = ScenarioProgram {
        initial: vec![9.76; n],
        phases: 3,
        phase_seconds: 1.5,
        clamp: (1e-3, f64::INFINITY),
        churn_floor: 0.05,
        seed: 13,
        events,
    };
    format!("# invariant: every-phase-gossips\n{}", program.dump())
}

#[test]
fn fuzz_replay_exits_nonzero_on_a_known_bad_dump() {
    let dir = std::env::temp_dir().join(format!("batopo-replay-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    let dump = dir.join("known_bad.scenario");
    std::fs::write(&dump, known_bad_dump()).expect("write dump");
    let bin = env!("CARGO_BIN_EXE_batopo");

    // Without --invariant, replay picks the suite from the dump header and
    // must exit nonzero on the still-failing violation.
    let out = std::process::Command::new(bin)
        .args(["fuzz", "replay", dump.to_str().unwrap()])
        .output()
        .expect("run batopo fuzz replay");
    assert!(!out.status.success(), "replay of a failing dump must exit nonzero");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(text.contains("every-phase-gossips"), "names the suite: {text}");
    assert!(text.contains("dump header"), "says where the default came from: {text}");

    // The same dump passes the (weaker) core suite when selected explicitly.
    let out = std::process::Command::new(bin)
        .args(["fuzz", "replay", dump.to_str().unwrap(), "--invariant", "core"])
        .output()
        .expect("run batopo fuzz replay");
    assert!(out.status.success(), "explicit --invariant core must exit zero");

    let _ = std::fs::remove_dir_all(&dir);
}
