//! Integration tests for the scenario DSL + the `batopo reproduce` harness:
//! the ScenarioBuilder compiles scripted events into well-formed traces, the
//! compiled traces round-trip through the dynamic consensus simulation, and
//! the `table1 --quick` reproduction target writes non-empty CSV artifacts
//! through the parallel sweep runner.

use batopo::bandwidth::dynamic::{
    simulate_dynamic_consensus, simulate_scripted_consensus, BandwidthTrace, DynamicPolicy,
};
use batopo::bandwidth::scenario_dsl::{ScenarioBuilder, ScenarioEvent};
use batopo::bench::experiments::{self, ExpOptions};

// ---------------------------------------------------------------------------
// ScenarioBuilder DSL
// ---------------------------------------------------------------------------

#[test]
fn builder_compiles_events_in_phase_order() {
    // Schedule out of order; the compiled schedule and trace must be
    // phase-ordered and apply-then-persist.
    let s = ScenarioBuilder::new(vec![9.76; 4])
        .phases(5)
        .at_phase(3)
        .link_degrade(&[0], 0.5)
        .at_phase(1)
        .set_bandwidth(0, 4.0)
        .at_phase(2)
        .report_stats("mid")
        .build();
    assert_eq!(s.num_phases(), 5);
    assert!(s.events.windows(2).all(|w| w[0].phase <= w[1].phase));
    assert_eq!(s.trace.phases[0][0], 9.76);
    assert_eq!(s.trace.phases[1][0], 4.0);
    assert_eq!(s.trace.phases[2][0], 4.0);
    assert_eq!(s.trace.phases[3][0], 2.0); // 4.0 × 0.5
    assert_eq!(s.trace.phases[4][0], 2.0);
    assert_eq!(s.reports, vec![(2, "mid".to_string())]);
    assert!(matches!(
        s.events.last().unwrap().event,
        ScenarioEvent::LinkDegrade { .. }
    ));
}

#[test]
fn builder_subsumes_the_legacy_trace_presets() {
    // The legacy constructors are now thin wrappers over the DSL; the DSL
    // spelled out by hand must produce bit-identical traces.
    let legacy = BandwidthTrace::random_walk(vec![9.76; 6], 8, 0.2, 1.0, 20.0, 1.0, 7);
    let dsl = ScenarioBuilder::new(vec![9.76; 6])
        .phases(8)
        .clamp(1.0, 20.0)
        .drift(0.2)
        .compile(7)
        .trace;
    assert_eq!(legacy.phases, dsl.phases);

    let legacy = BandwidthTrace::degradation(8, 9.76, 0.8, 5, 2, 1.5);
    let mut b = ScenarioBuilder::new(vec![9.76; 8]).phases(5).phase_seconds(1.5).at_phase(2);
    for i in 4..8 {
        b = b.set_bandwidth(i, 0.8);
    }
    let dsl = b.build().trace;
    assert_eq!(legacy.phases, dsl.phases);
    assert_eq!(legacy.phase_seconds, dsl.phase_seconds);
}

#[test]
fn builder_churn_floor_keeps_bandwidths_positive() {
    // A departed node must never hit bandwidth 0 (the time model divides by
    // b_min), and rejoin must restore the scripted value.
    let s = ScenarioBuilder::new(vec![9.76; 4])
        .phases(4)
        .at_phase(1)
        .node_churn(3, None)
        .at_phase(3)
        .node_churn(3, Some(9.76))
        .build();
    assert!(s.trace.phases.iter().flatten().all(|&b| b > 0.0));
    assert!(s.trace.phases[1][3] < 0.1);
    assert_eq!(s.trace.phases[3][3], 9.76);
}

// ---------------------------------------------------------------------------
// Scripted traces through the dynamic simulation
// ---------------------------------------------------------------------------

#[test]
fn scripted_trace_roundtrips_through_dynamic_consensus() {
    let scenario = ScenarioBuilder::new(vec![9.76; 8])
        .phases(3)
        .phase_seconds(1.0)
        .at_phase(1)
        .link_degrade(&[4, 5, 6, 7], 0.3)
        .report_stats("after degradation")
        .at_phase(2)
        .report_stats("end")
        .build();
    let policy = DynamicPolicy {
        r: 10,
        quick: true,
        ..Default::default()
    };

    // The plain trace entry point consumes the compiled trace...
    let run = simulate_dynamic_consensus(&scenario.trace, policy.clone(), false, 5);
    assert!(run.rounds > 0, "no gossip rounds executed");
    assert!(run.final_log_error < 0.0, "consensus error did not contract");

    // ...and the scripted entry point additionally materializes checkpoints.
    let scripted = simulate_scripted_consensus(&scenario, policy, false, 5);
    assert_eq!(scripted.outcome.rounds, run.rounds);
    assert_eq!(scripted.outcome.switches, run.switches);
    assert!((scripted.outcome.final_log_error - run.final_log_error).abs() < 1e-12);
    assert_eq!(scripted.reports.len(), 2);
    let after = &scripted.reports[0];
    assert_eq!((after.phase, after.label.as_str()), (1, "after degradation"));
    assert!(after.b_min > 0.0);
    assert!(after.sim_time > 0.0);
    let end = &scripted.reports[1];
    assert!(end.rounds >= after.rounds);
    assert!(
        end.log_error <= after.log_error + 1e-9,
        "error must not grow between checkpoints: {} vs {}",
        end.log_error,
        after.log_error
    );
}

// ---------------------------------------------------------------------------
// `batopo reproduce table1 --quick` (library-level)
// ---------------------------------------------------------------------------

#[test]
fn reproduce_table1_quick_writes_nonempty_csv() {
    let dir = std::env::temp_dir().join("batopo_reproduce_table1_test");
    std::fs::remove_dir_all(&dir).ok();
    let opts = ExpOptions {
        quick: true,
        out_dir: dir.clone(),
        seed: 42,
        ..Default::default()
    };
    experiments::run(&["table1".to_string()], &opts);

    let csv = std::fs::read_to_string(dir.join("table1.csv")).expect("table1.csv written");
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines[0], "n,topology,edges,r_asym,conv_time_ms");
    assert!(
        lines.len() > 1,
        "table1.csv has a header but no data rows:\n{csv}"
    );
    // Quick mode sweeps 7 sizes × 3 topology families.
    assert_eq!(lines.len() - 1, 7 * 3, "unexpected row count:\n{csv}");

    // The run manifest indexes the artifact deterministically.
    let manifest =
        std::fs::read_to_string(dir.join("run_manifest.json")).expect("run_manifest.json");
    assert!(manifest.contains("\"table1.csv\""), "{manifest}");
    assert!(manifest.contains("\"quick\":true"), "{manifest}");
    std::fs::remove_dir_all(&dir).ok();
}
