//! End-to-end tests for the candidate edge-support optimizer path
//! (`--candidates`): `full` parity with the legacy dense formulation,
//! union-of-baselines quality vs the dense solve and the ring, support
//! hygiene (dump/reload, disconnection rejection), and the pattern-Lanczos
//! projection regime above the dense cutoff.

use batopo::bandwidth::scenarios::BandwidthScenario;
use batopo::optimizer::{BaTopoOptimizer, OptimizeSpec};
use batopo::topo::baselines;
use batopo::topo::candidates::CandidateSet;

/// Debug-mode budgets: enough ADMM/extraction work to be representative,
/// small enough that the whole suite stays test-tier.
fn test_spec(scenario: BandwidthScenario, r: usize) -> OptimizeSpec {
    let mut s = OptimizeSpec::with_scenario(scenario, r);
    s.max_iters = 25;
    s.anneal_steps = 300;
    s.refine_iters = 100;
    s.polish_swaps = 10;
    s.restarts = 1;
    s
}

fn half_fast_bw(n: usize) -> BandwidthScenario {
    let mut bw = vec![9.76; n / 2];
    bw.extend(vec![3.25; n / 2]);
    BandwidthScenario::NodeLevel { bw }
}

fn solve(spec: &OptimizeSpec) -> batopo::optimizer::OptimizeReport {
    BaTopoOptimizer::new(spec.clone()).run_detailed().expect("solve")
}

#[test]
fn full_spec_reproduces_legacy_bitwise_on_paper_node_level() {
    // The paper's n=16 node-level scenario (§VI-A2): `--candidates full`
    // must dispatch to the untouched dense path and reproduce the legacy
    // run bit-for-bit — same edges, same r_asym bits, same residual bits.
    let legacy = test_spec(BandwidthScenario::paper_node_level(), 16);
    let mut full = legacy.clone();
    full.candidates = Some("full".into());
    let a = solve(&legacy);
    let b = solve(&full);
    assert_eq!(a.topology.graph.edges(), b.topology.graph.edges());
    assert_eq!(a.r_asym.to_bits(), b.r_asym.to_bits());
    assert_eq!(a.warm_start_r_asym.to_bits(), b.warm_start_r_asym.to_bits());
    assert_eq!(a.admm_iterations, b.admm_iterations);
    assert_eq!(a.final_residual.to_bits(), b.final_residual.to_bits());
    assert_eq!(a.krylov_iterations, b.krylov_iterations);
}

#[test]
fn union_quality_matches_dense_homogeneous() {
    // Homogeneous n=16/32: optimizing over the union-of-baselines support
    // must land within a small margin of the full dense solve (the union
    // contains every baseline design, so little quality is available only
    // off-support), and both must beat the ring.
    for n in [16usize, 32] {
        let d = (n as f64).log2().ceil() as usize;
        let r = n * d / 2;
        let dense = test_spec(BandwidthScenario::paper_homogeneous(n), r);
        let mut sparse = dense.clone();
        sparse.candidates = Some("union".into());
        let a = solve(&dense);
        let b = solve(&sparse);
        let ring = baselines::ring(n).asymptotic_convergence_factor();
        assert!(b.r_asym < ring, "n={n}: union {} vs ring {ring}", b.r_asym);
        assert!(
            b.r_asym <= a.r_asym + 0.08,
            "n={n}: union {} vs dense {}",
            b.r_asym,
            a.r_asym
        );
        assert_eq!(b.topology.num_edges(), r);
        assert!(b.constraint_check.is_ok(), "n={n}: {:?}", b.constraint_check);
    }
}

#[test]
fn union_quality_matches_dense_node_level() {
    // Heterogeneous counterpart on the paper's n=16 node-level scenario.
    let dense = test_spec(BandwidthScenario::paper_node_level(), 16);
    let mut sparse = dense.clone();
    sparse.candidates = Some("union".into());
    let a = solve(&dense);
    let b = solve(&sparse);
    assert!(b.constraint_check.is_ok(), "{:?}", b.constraint_check);
    assert_eq!(b.topology.num_edges(), 16);
    assert!(
        b.r_asym <= a.r_asym + 0.08,
        "union {} vs dense {}",
        b.r_asym,
        a.r_asym
    );
}

#[test]
fn union_scales_to_n64_hom_and_het() {
    // n=64 runs sparse-only (the dense counterpart is what the support
    // exists to avoid): homogeneous and heterogeneous solves must stay
    // feasible, connected, and clearly better than the ring.
    let n = 64usize;
    let r = n * 3; // 2r/n = 6: exact caps realizable inside the chorded ring
    let ring = baselines::ring(n).asymptotic_convergence_factor();
    for scenario in [BandwidthScenario::paper_homogeneous(n), half_fast_bw(n)] {
        let mut spec = test_spec(scenario, r);
        spec.max_iters = 15;
        spec.candidates = Some("union".into());
        let rep = solve(&spec);
        assert_eq!(rep.topology.num_edges(), r);
        assert!(rep.constraint_check.is_ok(), "{:?}", rep.constraint_check);
        assert!(rep.r_asym < ring, "union {} vs ring {ring}", rep.r_asym);
    }
}

#[test]
fn knn_support_above_dense_cutoff_uses_pattern_lanczos() {
    // n=192 sits above PATTERN_DENSE_CUTOFF (=160), so the NSD/PSD slack
    // projections run the iterative extreme-eigenpair clipping and r_asym
    // evaluation runs matrix-free — no O(n²) edge-variable state anywhere.
    let n = 192usize;
    let mut spec = test_spec(half_fast_bw(n), 2 * n);
    spec.max_iters = 6;
    spec.anneal_steps = 0;
    spec.refine_iters = 40;
    spec.polish_swaps = 0;
    spec.candidates = Some("knn:8".into());
    let rep = solve(&spec);
    assert_eq!(rep.topology.num_edges(), 2 * n);
    assert_eq!(rep.krylov_failures, 0, "stalled X-step solves");
    assert!(rep.r_asym > 0.0 && rep.r_asym < 1.0, "r_asym={}", rep.r_asym);
    // The topology itself must live on the generated support.
    let cand = CandidateSet::generate("knn:8", &spec.scenario, spec.seed).unwrap();
    for &(a, b) in rep.topology.graph.edges() {
        assert!(cand.position(a, b).is_some(), "off-support edge ({a},{b})");
    }
}

#[test]
fn support_dump_reload_roundtrip() {
    let sc = BandwidthScenario::paper_homogeneous(32);
    let cand = CandidateSet::generate("union", &sc, 9).unwrap();
    let j = cand.to_json();
    // Through a real serialize → parse cycle, not just the Json tree.
    let text = format!("{j}");
    let parsed = batopo::util::json::Json::parse(&text).expect("parse dumped support");
    let back = CandidateSet::from_json(&parsed).expect("reload");
    assert_eq!(back.n(), cand.n());
    assert_eq!(back.edges(), cand.edges());
    assert_eq!(back.spec(), cand.spec());
}

#[test]
fn disconnected_user_support_rejected() {
    // Two components: strict constructors must refuse with a clean message;
    // generator outputs never hit this (spanning-ring augmentation).
    let edges = vec![(0, 1), (1, 2), (3, 4), (4, 5)];
    let err = CandidateSet::from_edges(6, edges, "edges").unwrap_err();
    assert!(err.contains("does not connect"), "{err}");
    let ok = CandidateSet::from_edges_augmented(6, vec![(0, 3)], "edges").unwrap();
    assert!(ok.len() >= 6);
}
