//! Solver-stack integration tests for the `LinearOperator` refactor and the
//! `batopo bench` subsystem:
//!
//! - operator parity: dense vs CSC vs matrix-free Laplacian matvecs agree to
//!   1e-12 on random graphs (property test),
//! - Lanczos λ₂ / r_asym agreement with the dense eigensolver up to n = 256,
//! - the matrix-free scale regime (n = 2048) that the dense path cannot run,
//! - `bench --quick --json` round-trip: emitted `BenchRecord` JSON parses
//!   back and satisfies the schema the CI perf gate consumes.

use batopo::bandwidth::scenarios::BandwidthScenario;
use batopo::bench::perf::{perf_scale, PerfOptions};
use batopo::bench::records::{self, BenchRecord};
use batopo::graph::laplacian::{
    laplacian_from_weights, laplacian_triplets, weight_matrix_from_edge_weights,
};
use batopo::graph::spectral::{
    asymptotic_convergence_factor, asymptotic_convergence_factor_lanczos,
    laplacian_eigenvalues, laplacian_extremes_lanczos,
};
use batopo::graph::Graph;
use batopo::linalg::{
    bicgstab, cg, BicgstabOptions, CgOptions, CscMatrix, CsrMatrix, DenseMatrix, GossipOperator,
    LanczosOptions, LaplacianOperator, LinearOperator, SymEigen,
};
use batopo::optimizer::{operators, BaTopoOptimizer, OptimizeSpec, XStep};
use batopo::topo::baselines::chorded_ring_graph;
use batopo::topo::weights::metropolis;
use batopo::util::prop::Runner;

// ---------------------------------------------------------------------------
// Operator parity (dense == CSC == CSR == matrix-free)
// ---------------------------------------------------------------------------

#[test]
fn prop_operator_parity_on_random_graphs() {
    Runner::new("dense/CSC/CSR/matrix-free Laplacian matvecs agree", 30).run(|g| {
        let n = g.usize_in(3..40);
        let edges = g.connected_edges(n, 0.3);
        let graph = Graph::new(n, edges);
        let w: Vec<f64> = (0..graph.num_edges()).map(|_| g.f64_in(0.05..1.0)).collect();

        let dense = laplacian_from_weights(&graph, &w);
        let csc = CscMatrix::from_triplets(n, n, laplacian_triplets(&graph, &w));
        let csr = CsrMatrix::from_csc(&csc).with_threads(3);
        let free = LaplacianOperator::new(n, graph.edges(), &w);

        let x: Vec<f64> = (0..n).map(|_| g.gaussian()).collect();
        let y_dense = dense.apply_vec(&x);
        let y_csc = csc.apply_vec(&x);
        let y_csr = csr.apply_vec(&x);
        let y_free = free.apply_vec(&x);
        for i in 0..n {
            assert!((y_dense[i] - y_csc[i]).abs() < 1e-12, "csc row {i}");
            assert!((y_dense[i] - y_csr[i]).abs() < 1e-12, "csr row {i}");
            assert!((y_dense[i] - y_free[i]).abs() < 1e-12, "matrix-free row {i}");
        }

        // Gossip operator parity against the assembled W.
        let wm = weight_matrix_from_edge_weights(&graph, &w);
        let gossip = GossipOperator::new(n, graph.edges(), &w);
        let y_wm = wm.apply_vec(&x);
        let y_go = gossip.apply_vec(&x);
        for i in 0..n {
            assert!((y_wm[i] - y_go[i]).abs() < 1e-12, "gossip row {i}");
        }
    });
}

#[test]
fn bicgstab_is_operator_generic() {
    // The same Laplacian-plus-shift system solved through three operator
    // backends must give the same solution.
    let n = 60;
    let edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    let graph = Graph::new(n, edges);
    let w = vec![1.0; graph.num_edges()];
    let mut trips = laplacian_triplets(&graph, &w);
    for i in 0..n {
        trips.push((i, i, 1.0)); // shift: L + I is SPD
    }
    let csc = CscMatrix::from_triplets(n, n, trips);
    let csr = CsrMatrix::from_csc(&csc);
    let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let opts = BicgstabOptions::default();
    let (x_csc, out_csc) = bicgstab(&csc, &b, None, &opts);
    let (x_csr, out_csr) = bicgstab(&csr, &b, None, &opts);
    assert!(out_csc.converged && out_csr.converged);
    for i in 0..n {
        assert!((x_csc[i] - x_csr[i]).abs() < 1e-6, "row {i}");
    }
}

// ---------------------------------------------------------------------------
// Lanczos vs dense eigensolver, up to n = 256
// ---------------------------------------------------------------------------

#[test]
fn lanczos_lambda2_matches_dense_up_to_256() {
    for n in [32usize, 96, 256] {
        let graph = chorded_ring_graph(n);
        let w = metropolis(&graph);
        let l = laplacian_from_weights(&graph, &w);
        let vals = laplacian_eigenvalues(&l);
        let (dense_lam2, dense_max) = (vals[vals.len() - 2], vals[0]);
        let (lam2, lam_max) =
            laplacian_extremes_lanczos(&graph, &w, &LanczosOptions::default());
        assert!(
            (lam2 - dense_lam2).abs() < 1e-6,
            "n={n}: λ₂ lanczos {lam2} vs dense {dense_lam2}"
        );
        assert!(
            (lam_max - dense_max).abs() < 1e-6,
            "n={n}: λ_max lanczos {lam_max} vs dense {dense_max}"
        );
    }
}

#[test]
fn lanczos_r_asym_matches_dense_up_to_256() {
    for n in [64usize, 256] {
        let graph = chorded_ring_graph(n);
        let w = metropolis(&graph);
        let dense = asymptotic_convergence_factor(&weight_matrix_from_edge_weights(&graph, &w));
        let lanczos =
            asymptotic_convergence_factor_lanczos(&graph, &w, &LanczosOptions::default());
        assert!(
            (dense - lanczos).abs() < 1e-6,
            "n={n}: r_asym lanczos {lanczos} vs dense {dense}"
        );
    }
}

#[test]
fn matrix_free_scale_regime_runs_at_2048() {
    // The regime the dense path cannot reach (an O(n³) Jacobi sweep on an
    // assembled 2048² matrix): the matrix-free Lanczos path completes and
    // returns a sane contracting spectrum.
    let n = 2048;
    let graph = chorded_ring_graph(n);
    let w = metropolis(&graph);
    let (lam2, lam_max) = laplacian_extremes_lanczos(&graph, &w, &LanczosOptions::default());
    assert!(lam2 > 1e-4, "connected graph must have λ₂ > 0, got {lam2}");
    assert!(lam_max > lam2);
    assert!(lam_max < 2.0 + 1e-9, "metropolis Laplacian is bounded by 2");
    let r = asymptotic_convergence_factor_lanczos(&graph, &w, &LanczosOptions::default());
    assert!(r > 0.0 && r < 1.0, "r_asym {r} must contract");
}

// ---------------------------------------------------------------------------
// bench --quick --json round-trip (the CI perf-smoke contract)
// ---------------------------------------------------------------------------

fn check_record_schema(r: &BenchRecord) {
    assert!(!r.name.is_empty());
    assert!(r.iters >= 1, "{}: iters {}", r.name, r.iters);
    assert!(r.mean_ns > 0.0, "{}: mean {}", r.name, r.mean_ns);
    assert!(r.p50_ns > 0.0);
    assert!(r.p95_ns >= r.p50_ns * 0.999, "{}: p95 below p50", r.name);
    assert!(r.throughput_per_s > 0.0);
    assert!(!r.git_rev.is_empty());
}

#[test]
fn bench_quick_json_roundtrip() {
    // Tiny sizes so the scale target runs in test time; the emitted file
    // must parse back into schema-valid records.
    let opts = PerfOptions {
        quick: true,
        threads: 2,
        sizes: Some(vec![64]),
    };
    let recs = perf_scale(&opts);
    assert!(
        recs.len() >= 4,
        "scale must emit lanczos + r_asym + 2 spmv records, got {}",
        recs.len()
    );
    for r in &recs {
        check_record_schema(r);
        assert_eq!(r.n, 64);
    }

    let dir = std::env::temp_dir().join("batopo_bench_json_test");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("BENCH_scale.json");
    records::write_records(&path, "scale", true, &recs).unwrap();
    let back = records::read_records(&path).unwrap();
    assert_eq!(back, recs);

    // The emitted file is a valid gate baseline for itself: zero regressions.
    let rep = records::compare(&back, &recs, 1.25, 0.0);
    assert_eq!(rep.compared, recs.len());
    assert!(rep.regressions.is_empty());
    assert_eq!(rep.missing_baseline, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn committed_baseline_parses_and_gates() {
    // The checked-in BENCH_baseline.json must always satisfy the schema —
    // this is the file the CI perf gate trusts.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_baseline.json");
    let baseline = records::read_records(&path).unwrap();
    assert!(!baseline.is_empty());
    for r in &baseline {
        check_record_schema(r);
    }
    // Identical records pass the gate; a 2x slowdown on every record fails it.
    let rep = records::compare(&baseline, &baseline, 1.25, 0.0);
    assert!(rep.regressions.is_empty());
    let slowed: Vec<BenchRecord> = baseline
        .iter()
        .map(|r| BenchRecord {
            mean_ns: r.mean_ns * 2.0,
            ..r.clone()
        })
        .collect();
    let rep = records::compare(&baseline, &slowed, 1.25, 0.0);
    assert_eq!(rep.regressions.len(), baseline.len());
}

// ---------------------------------------------------------------------------
// The CG Schur-complement X-step (paper §V-C)
// ---------------------------------------------------------------------------

#[test]
fn prop_cg_matches_dense_direct_solve_on_random_spd() {
    // CG against an eigendecomposition-based direct solve on random SPD
    // systems `B·Bᵀ + I`.
    Runner::new("CG agrees with the dense direct solve on SPD systems", 12).run(|g| {
        let n = g.usize_in(4..32);
        let mut b = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = g.gaussian() * 0.4;
            }
        }
        let mut spd = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    acc += b[(i, k)] * b[(j, k)];
                }
                spd[(i, j)] = acc;
            }
        }
        let rhs: Vec<f64> = (0..n).map(|_| g.gaussian()).collect();
        let eig = SymEigen::new(&spd);
        let mut direct = vec![0.0; n];
        for (k, lam) in eig.values.iter().enumerate() {
            let mut coef = 0.0;
            for i in 0..n {
                coef += eig.vectors[(i, k)] * rhs[i];
            }
            coef /= lam;
            for i in 0..n {
                direct[i] += coef * eig.vectors[(i, k)];
            }
        }
        let (x, out) = cg(
            &spd,
            &rhs,
            None,
            &CgOptions {
                rtol: 1e-12,
                ..Default::default()
            },
        );
        assert!(out.converged, "{out:?}");
        for i in 0..n {
            assert!(
                (x[i] - direct[i]).abs() < 1e-7,
                "row {i}: cg {} vs direct {}",
                x[i],
                direct[i]
            );
        }
    });
}

#[test]
fn normal_operator_matches_explicit_schur_matrix() {
    // The matrix-free `A Aᵀ + δI` apply must equal the explicitly assembled
    // Schur complement on both problem forms.
    let delta = 1e-8;
    let het_cs = BandwidthScenario::NodeLevel {
        bw: vec![9.76, 9.76, 9.76, 9.76, 3.25, 3.25, 3.25, 3.25],
    }
    .constraints(10)
    .unwrap();
    for (ops, tag) in [
        (operators::build_homogeneous(8, 2.0, delta), "homogeneous"),
        (
            operators::build_heterogeneous(&het_cs, 2.0, delta),
            "heterogeneous",
        ),
    ] {
        let nr = ops.layout.rows;
        let a_dense = ops.a.to_dense();
        // Explicit Schur complement (dense; test sizes only).
        let mut schur = DenseMatrix::zeros(nr, nr);
        for i in 0..nr {
            for j in 0..nr {
                let mut acc = if i == j { delta } else { 0.0 };
                for k in 0..ops.layout.total {
                    acc += a_dense[(i, k)] * a_dense[(j, k)];
                }
                schur[(i, j)] = acc;
            }
        }
        let normal = ops.normal_operator();
        let x: Vec<f64> = (0..nr).map(|i| ((i * 37 % 19) as f64 - 9.0) * 0.1).collect();
        let want = schur.apply_vec(&x);
        let got = normal.apply_vec(&x);
        for i in 0..nr {
            assert!(
                (want[i] - got[i]).abs() < 1e-9,
                "{tag} row {i}: explicit {} vs matrix-free {}",
                want[i],
                got[i]
            );
        }
    }
}

/// End-to-end X-step backend equivalence: both backends solve the same
/// δ-regularized linear system, so the full pipeline (warm start → ADMM →
/// extraction → polish, all seeded) must land on the same edge support with
/// matching `r_asym`. The n=16 node-level cell is the paper scenario the
/// acceptance criteria lock.
#[test]
fn xstep_backends_reach_equivalent_topologies() {
    let node_level_32 = batopo::config::scenario_by_name("node-level", 32).unwrap();
    let cells: Vec<(BandwidthScenario, usize, &str)> = vec![
        (BandwidthScenario::paper_homogeneous(16), 32, "hom n=16"),
        (BandwidthScenario::paper_homogeneous(32), 80, "hom n=32"),
        (BandwidthScenario::paper_node_level(), 32, "node-level n=16"),
        (node_level_32, 80, "node-level n=32"),
    ];
    for (scenario, r, tag) in cells {
        let mut spec = OptimizeSpec::with_scenario(scenario, r);
        spec.max_iters = 60;
        spec.anneal_steps = 300;
        spec.refine_iters = 100;
        spec.polish_swaps = 8;
        spec.restarts = 1;
        let mut s_cg = spec.clone();
        s_cg.xstep = XStep::Cg;
        let mut s_kkt = spec;
        s_kkt.xstep = XStep::Bicgstab;
        let rep_cg = BaTopoOptimizer::new(s_cg).run_detailed().expect("cg solve");
        let rep_kkt = BaTopoOptimizer::new(s_kkt).run_detailed().expect("kkt solve");
        assert_eq!(
            rep_cg.topology.graph.edge_indices(),
            rep_kkt.topology.graph.edge_indices(),
            "{tag}: extracted supports differ"
        );
        assert!(
            (rep_cg.r_asym - rep_kkt.r_asym).abs() < 1e-6,
            "{tag}: r_asym cg {} vs kkt {}",
            rep_cg.r_asym,
            rep_kkt.r_asym
        );
        assert_eq!(rep_cg.krylov_failures, 0, "{tag}: cg had stalled solves");
        assert_eq!(rep_kkt.krylov_failures, 0, "{tag}: kkt had stalled solves");
    }
}

// ---------------------------------------------------------------------------
// Dense ↔ Lanczos dispatch boundary (LANCZOS_CUTOFF)
// ---------------------------------------------------------------------------

/// All three `r_asym` call sites funnel through the same dispatch:
/// `Topology::asymptotic_convergence_factor` (the experiment drivers),
/// `optimizer::extract::asym`, and the ADMM candidate scoring — the latter
/// two via `spectral::r_asym_graph`. At the `LANCZOS_CUTOFF` boundary
/// (n = 159/160 dense, n = 161 Lanczos) every entry point must agree with
/// both underlying paths, or the optimizer would silently mis-rank
/// candidates straddling the cutoff.
#[test]
fn r_asym_dispatch_agrees_across_the_lanczos_cutoff() {
    use batopo::graph::spectral::{r_asym_graph, LANCZOS_CUTOFF};
    use batopo::graph::Topology;
    assert_eq!(LANCZOS_CUTOFF, 160, "boundary sizes below track the cutoff");
    for n in [LANCZOS_CUTOFF - 1, LANCZOS_CUTOFF, LANCZOS_CUTOFF + 1] {
        let graph = chorded_ring_graph(n);
        let w = metropolis(&graph);
        let wm = weight_matrix_from_edge_weights(&graph, &w);

        let dense = asymptotic_convergence_factor(&wm);
        let lanczos = asymptotic_convergence_factor_lanczos(&graph, &w, &LanczosOptions::default());
        let dispatch = r_asym_graph(&graph, &w);
        let topo = Topology::new(graph.clone(), wm, format!("boundary_n{n}"));
        let via_topology = topo.asymptotic_convergence_factor();

        // Both paths agree tightly on expanders…
        assert!(
            (dense - lanczos).abs() < 1e-6,
            "n={n}: dense {dense} vs lanczos {lanczos}"
        );
        // …and each public entry point lands exactly on its dispatch side.
        let expected = if n <= LANCZOS_CUTOFF { dense } else { lanczos };
        assert_eq!(dispatch, expected, "r_asym_graph dispatch at n={n}");
        assert_eq!(via_topology, expected, "Topology dispatch at n={n}");
    }
}

/// Same boundary check for the algebraic-connectivity dispatch used by the
/// constraint diagnostics.
#[test]
fn algebraic_connectivity_dispatch_agrees_across_the_cutoff() {
    use batopo::graph::spectral::{algebraic_connectivity_graph, LANCZOS_CUTOFF};
    for n in [LANCZOS_CUTOFF - 1, LANCZOS_CUTOFF, LANCZOS_CUTOFF + 1] {
        let graph = chorded_ring_graph(n);
        let w = metropolis(&graph);
        let l = laplacian_from_weights(&graph, &w);
        let vals = laplacian_eigenvalues(&l);
        let dense_lam2 = vals[vals.len() - 2];
        let auto = algebraic_connectivity_graph(&graph, &w);
        assert!(
            (auto - dense_lam2).abs() < 1e-6,
            "n={n}: dispatch {auto} vs dense λ₂ {dense_lam2}"
        );
    }
}
