//! `cargo bench` entrypoint (custom harness; criterion is unavailable in the
//! offline crate set). Regenerates every paper table/figure plus the perf
//! micro-benches:
//!
//! ```text
//! cargo bench                 # everything at CI budgets (~15 min)
//! cargo bench -- fig1 table1  # selected experiments (full budgets)
//! cargo bench -- perf         # perf benches only (mixing+solver+admm+scale)
//! cargo bench -- scale        # one perf target
//! cargo bench -- all --full   # everything at paper budgets (hours)
//! ```
//!
//! The perf benches are also available as `batopo bench <target> --json …`,
//! which additionally persists schema-stable `BenchRecord` JSON for the CI
//! perf-regression gate (docs/BENCHMARKS.md).
//!
//! Optimized BA-Topo instances are cached under `results/topos/`; a plain
//! `cargo bench` after a full per-figure run reuses the full-quality
//! topologies.
//!
//! Outputs land in `results/` (CSV per figure/table).

use batopo::bench::{experiments, perf};
use batopo::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // `cargo bench` passes `--bench`; ignore it.
    let mut names: Vec<String> = args
        .positional()
        .iter()
        .filter(|s| !s.starts_with("--") && *s != "bench")
        .cloned()
        .collect();
    // A bare `cargo bench` (no experiment names) runs everything at CI
    // budgets so the default invocation stays tractable; named experiments
    // default to full budgets. `--quick` / `--full` override either way.
    let bare = names.is_empty();
    if bare {
        names.push("all".to_string());
    }
    let quick = if args.flag("full") {
        false
    } else {
        args.flag("quick") || bare
    };
    let mut opts = experiments::ExpOptions {
        quick,
        out_dir: args.str_or("out", "results").into(),
        seed: args.parse_or("seed", 42u64).unwrap(),
        ..Default::default()
    };
    opts.override_threads(args.parse_or("threads", 0usize).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    }));
    println!(
        "batopo bench: experiments {:?} (quick={}, out={})",
        names,
        opts.quick,
        opts.out_dir.display()
    );
    let t0 = std::time::Instant::now();
    experiments::run(&names, &opts);
    perf::run(&names, &opts);
    if names.iter().any(|n| n == "ablations") {
        batopo::bench::ablations::run_ablations(&opts);
    }
    println!("bench total: {:.1}s", t0.elapsed().as_secs_f64());
}
